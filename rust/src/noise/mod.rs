//! §5.3's noise analysis: the lumped-noise error model, SINAD
//! characterization of each dataflow (Fig. 9), and the native Monte-Carlo
//! driver used when the PJRT artifacts are not available.
//!
//! The PJRT path (runtime + mc_opt/mc_naive artifacts) runs the *trained*
//! NeuralPeriph circuits; this module adds (a) the analytical per-strategy
//! SINAD from the bit-exact behavioural models (the ISAAC/CASCADE markers
//! of Fig. 10), and (b) the Eq.-(13) noise-to-accuracy machinery.

use crate::arch::crossbar::Group;
use crate::util::pool;
use crate::util::rng::Pcg;
use crate::util::stats;

/// Draw one correlated (realistic) input vector for a kernel: inputs
/// biased along the kernel's sign pattern, like post-ReLU activations
/// against a trained filter (see model.py's rationale).
pub fn correlated_sample(rng: &mut Pcg, w: &[i32]) -> Vec<u32> {
    let corr = rng.range(-1.0, 1.0);
    w.iter()
        .map(|wi| {
            let base = rng.below(128) as f64;
            let v = base + corr * 127.0 * (wi.signum() as f64);
            v.round().clamp(0.0, 255.0) as u32
        })
        .collect()
}

/// A random kernel plus `n` correlated input vectors drawn from one
/// sequential stream (see [`correlated_sample`]).
pub fn correlated_batch(rng: &mut Pcg, n: usize, rows: usize)
                        -> (Group, Vec<Vec<u32>>) {
    let w: Vec<i32> = (0..rows).map(|_| rng.below(255) as i32 - 127).collect();
    let group = Group { w };
    let xs = (0..n).map(|_| correlated_sample(rng, &group.w)).collect();
    (group, xs)
}

/// Per-strategy SINAD at the dot-product level from the behavioural
/// models — the Fig. 10 vertical markers for the baseline dataflows.
/// Strategy A: ISAAC's multiplicative quantization noise (8-bit ADC per
/// conversion); Strategy B: CASCADE's 6-bit buffer cells + write
/// variation. The Neural-PIM marker comes from the PJRT MC experiment.
///
/// Each Monte-Carlo trial runs on its own [`Pcg::fork`]ed stream (forked
/// sequentially from the master seed up front), so the trials parallelize
/// across the worker pool while the result stays bit-identical to a
/// sequential run at any `--threads` count.
pub fn strategy_sinad(strategy: char, n: usize, seed: u64) -> f64 {
    strategy_sinad_with(pool::threads(), strategy, n, seed)
}

/// [`strategy_sinad`] at an explicit worker count (the determinism tests
/// compare 1/2/8 without touching the process-global pool size).
fn strategy_sinad_with(n_threads: usize, strategy: char, n: usize,
                       seed: u64) -> f64 {
    let mut master = Pcg::new(seed);
    let w: Vec<i32> =
        (0..128).map(|_| master.below(255) as i32 - 127).collect();
    let group = Group { w };
    let streams: Vec<Pcg> = (0..n).map(|t| master.fork(t as u64)).collect();
    let pairs: Vec<(f64, f64)> = pool::map_with(n_threads, &streams, |stream| {
        let mut rng = stream.clone();
        let x = correlated_sample(&mut rng, &group.w);
        let d = group.dot(&x) as f64;
        let hw = match strategy {
            'A' => group.strategy_a(&x, 1, 255.0, 128),
            'B' => strategy_b_once(&group, &x, &mut rng),
            'C' => group.strategy_c(&x, 4, 255.0, 128.0 * 255.0 * 127.0),
            _ => panic!("unknown strategy"),
        };
        (hw, d)
    });
    let d_hw: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let d_sw: Vec<f64> = pairs.iter().map(|p| p.1).collect();
    stats::sinad_db(&d_hw, &d_sw)
}

/// Behavioural CASCADE dataflow for one dot product: partial sums written
/// to 6-bit buffer cells with lognormal write variation, accumulated
/// along radix diagonals, quantized at 10 bits (Eq. 3), digital S+A.
pub fn strategy_b_once(group: &Group, x: &[u32], rng: &mut Pcg) -> f64 {
    let pd = 1u32;
    let partial = group.partial_sums(x, pd);
    let fs = 128.0 * (2f64.powi(pd as i32) - 1.0);
    let buf_levels = 63.0; // 6-bit cells (Fig. 10 discussion)
    let adc_levels = 1023.0; // 10-bit (Table 3)
    let sigma = 0.025;
    let n_exp = (partial.len() - 1) + 8;
    let mut diag_p = vec![0.0f64; n_exp + 1];
    let mut diag_n = vec![0.0f64; n_exp + 1];
    let mut count = vec![0u32; n_exp + 1];
    for (s, planes) in partial.iter().enumerate() {
        for (j, &v) in planes.iter().enumerate() {
            // differential -> two physical BLs
            let (pp, pn) = if v >= 0 { (v as f64, 0.0) } else { (0.0, -v as f64) };
            let e = s + j;
            let wp = crate::arch::quantize_uniform(pp, buf_levels, fs)
                * rng.lognormal_factor(sigma);
            let wn = crate::arch::quantize_uniform(pn, buf_levels, fs)
                * rng.lognormal_factor(sigma);
            diag_p[e] += wp;
            diag_n[e] += wn;
            count[e] += 1;
        }
    }
    let mut total = 0.0;
    for e in 0..=n_exp {
        if count[e] == 0 {
            continue;
        }
        let fs_bl = fs * count[e] as f64;
        let qp = crate::arch::quantize_uniform(diag_p[e], adc_levels, fs_bl);
        let qn = crate::arch::quantize_uniform(diag_n[e], adc_levels, fs_bl);
        total += 2f64.powi(e as i32) * (qp - qn);
    }
    total.round()
}

/// Eq. (13): the noise sigma injected into activations at a given SINAD.
pub fn injection_sigma(max_abs_activation: f64, sinad_db: f64) -> f64 {
    max_abs_activation / 10f64.powf(sinad_db / 20.0)
}

/// Result of one Fig. 9 Monte-Carlo run (wheither PJRT or native).
#[derive(Debug, Clone)]
pub struct McResult {
    pub sinad_db: f64,
    pub err_mean: f64,
    pub err_rms: f64,
    pub err_min: f64,
    pub err_max: f64,
    pub n: usize,
}

pub fn mc_result(d_hw: &[f64], d_sw: &[f64]) -> McResult {
    let err: Vec<f64> = d_hw.iter().zip(d_sw).map(|(h, s)| h - s).collect();
    McResult {
        sinad_db: stats::sinad_db(d_hw, d_sw),
        err_mean: stats::mean(&err),
        err_rms: stats::std(&err),
        err_min: stats::min(&err),
        err_max: stats::max(&err),
        n: err.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_ordering_a_above_b() {
        // Fig. 10: CASCADE's dataflow has the lowest SINAD (6-bit buffer
        // cells + write variation); ISAAC's quantization-only noise is
        // higher.
        let a = strategy_sinad('A', 400, 1);
        let b = strategy_sinad('B', 400, 1);
        assert!(a > b, "A {a} dB vs B {b} dB");
        assert!(b > 5.0, "B implausibly low: {b}");
    }

    #[test]
    fn ideal_strategy_c_is_cleanest() {
        // without circuit noise, C at 8-bit range-aware conversion beats
        // B (the trained-circuit C comes from the PJRT MC instead)
        let b = strategy_sinad('B', 400, 2);
        let c = strategy_sinad('C', 400, 2);
        assert!(c > b, "C {c} vs B {b}");
    }

    #[test]
    fn strategy_sinad_thread_count_invariant() {
        // same seed => bit-identical SINAD at 1, 2, and 8 threads (the
        // per-trial forked streams make the MC order-independent)
        let base = strategy_sinad_with(1, 'B', 96, 11).to_bits();
        for t in [2usize, 8] {
            let got = strategy_sinad_with(t, 'B', 96, 11).to_bits();
            assert_eq!(got, base, "threads = {t}");
        }
    }

    #[test]
    fn injection_sigma_eq13() {
        // SINAD = 20 dB -> sigma = max/10
        assert!((injection_sigma(5.0, 20.0) - 0.5).abs() < 1e-12);
        // higher SINAD -> less noise
        assert!(injection_sigma(1.0, 50.0) < injection_sigma(1.0, 40.0));
    }

    #[test]
    fn mc_result_statistics() {
        let sw = vec![0.0, 10.0, 20.0, 30.0];
        let hw = vec![1.0, 11.0, 19.0, 31.0];
        let r = mc_result(&hw, &sw);
        assert_eq!(r.n, 4);
        assert!((r.err_mean - 0.5).abs() < 1e-12);
        assert!(r.err_max == 1.0 && r.err_min == -1.0);
    }
}
