//! Dynamic batcher: collect up to `max_batch` requests, waiting at most
//! `max_wait` after the first arrival — the standard serving trade-off
//! between batch efficiency and tail latency.

use super::Request;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

pub struct Batcher {
    policy: BatchPolicy,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy }
    }

    /// Collect one batch. Returns None when the channel is closed and
    /// fully drained (shutdown).
    pub fn collect(&self, rx: &Receiver<Request>) -> Option<Vec<Request>> {
        // block for the first request
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return None,
        };
        let mut out = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while out.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => out.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::sync::mpsc;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        Request {
            id,
            image: vec![0.0; 4],
            respond: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn batch_respects_capacity() {
        let (tx, rx) = mpsc::channel();
        for i in 0..10 {
            tx.send(req(i)).unwrap();
        }
        let b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        });
        let got = b.collect(&rx).unwrap();
        assert_eq!(got.len(), 4);
        // FIFO order preserved
        assert_eq!(got.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = mpsc::channel();
        for i in 0..3 {
            tx.send(req(i)).unwrap();
        }
        drop(tx);
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        });
        assert_eq!(b.collect(&rx).unwrap().len(), 3);
        assert!(b.collect(&rx).is_none());
    }

    #[test]
    fn property_never_exceeds_capacity_and_fifo() {
        prop::check("batcher capacity + FIFO", 50, |g| {
            let cap = g.usize_in(1, 16);
            let n = g.usize_in(1, 64);
            let (tx, rx) = mpsc::channel();
            for i in 0..n {
                tx.send(req(i as u64)).unwrap();
            }
            drop(tx);
            let b = Batcher::new(BatchPolicy {
                max_batch: cap,
                max_wait: Duration::from_millis(0),
            });
            let mut seen = Vec::new();
            while let Some(batch) = b.collect(&rx) {
                crate::prop_assert!(batch.len() <= cap, "over capacity");
                crate::prop_assert!(!batch.is_empty(), "empty batch");
                seen.extend(batch.iter().map(|r| r.id));
            }
            crate::prop_assert!(
                seen == (0..n as u64).collect::<Vec<_>>(),
                "lost or reordered requests: {:?}", seen
            );
            Ok(())
        });
    }
}
