//! L3 inference coordinator: the deployable serving layer.
//!
//! Requests (single images) arrive on a shared multi-consumer queue; a
//! dynamic batcher groups them up to the artifact's fixed batch (padding
//! the tail), worker threads execute the compiled PJRT executable, and
//! responses fan back out to the callers. std::thread based (the offline
//! registry has no tokio); the architecture mirrors a vLLM-style router:
//! admission queue -> batcher -> execution engine -> response demux.
//!
//! N workers collect and execute batches concurrently: the queue releases
//! its lock while a worker waits (see `queue.rs`), so one worker's fill
//! window never blocks the others.
//!
//! PJRT objects are thread-local (`Rc` + raw pointers inside the xla
//! crate), so every worker owns its *own* client + executable, built
//! inside the worker thread; only plain `Vec<f32>` data crosses threads.

pub mod batcher;
pub mod queue;

use crate::runtime::{self, Runtime};
use anyhow::{anyhow, Result};
use batcher::{BatchPolicy, Batcher};
use queue::SharedQueue;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One inference request: a single image (u8-valued f32 HWC).
pub struct Request {
    pub id: u64,
    pub image: Vec<f32>,
    pub respond: mpsc::Sender<Response>,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub queue_us: u64,
    pub exec_us: u64,
    pub batch_size: usize,
    /// `Some(cause)` when the batch this request rode in failed; `logits`
    /// is empty then. Lets callers distinguish batch failure (an error
    /// response arrives) from shutdown (the response channel disconnects).
    pub error: Option<String>,
}

/// Thread-safe description of a non-image executable input; each worker
/// materializes the literal locally.
#[derive(Debug, Clone)]
pub enum ExtraInput {
    ScalarF32(f32),
    KeyU32(u64),
}

impl ExtraInput {
    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            ExtraInput::ScalarF32(v) => Ok(runtime::lit_scalar_f32(*v)),
            ExtraInput::KeyU32(seed) => runtime::lit_key(*seed),
        }
    }
}

/// Sliding window of per-request latencies retained for the percentile
/// summary (bounds memory on long-running deployments).
pub const LATENCY_WINDOW: usize = 16_384;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    /// requests whose batch execution failed (error responses sent)
    pub failed: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    pub exec_us_total: AtomicU64,
    pub queue_us_total: AtomicU64,
    /// most recent per-request total latencies (µs), capped at
    /// [`LATENCY_WINDOW`]; powers the p50/p99 in [`Metrics::summary`] —
    /// the same `util::stats::percentile` path the event simulator's
    /// request-level mode reports through
    pub lat_us: Mutex<VecDeque<u64>>,
}

impl Metrics {
    /// Record one served request's total (queue + exec) latency.
    pub fn record_latency_us(&self, us: u64) {
        if let Ok(mut w) = self.lat_us.lock() {
            if w.len() == LATENCY_WINDOW {
                w.pop_front();
            }
            w.push_back(us);
        }
    }

    /// Sorted snapshot of the latency window, in milliseconds (one lock
    /// acquisition + one sort, however many percentiles are read off it).
    fn latency_snapshot_ms(&self) -> Vec<f64> {
        let mut lat: Vec<f64> = match self.lat_us.lock() {
            Ok(w) => w.iter().map(|&u| u as f64 / 1000.0).collect(),
            Err(_) => return Vec::new(),
        };
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        lat
    }

    /// Percentile over the retained latency window, in milliseconds.
    pub fn latency_percentile_ms(&self, p: f64) -> f64 {
        crate::util::stats::percentile_sorted(&self.latency_snapshot_ms(), p)
    }

    pub fn summary(&self) -> String {
        let reqs_raw = self.requests.load(Ordering::Relaxed);
        let pad = self.padded_slots.load(Ordering::Relaxed);
        let slots = reqs_raw + pad;
        let pad_frac = if slots == 0 { 0.0 } else { pad as f64 / slots as f64 };
        let reqs = reqs_raw.max(1);
        let batches = self.batches.load(Ordering::Relaxed).max(1);
        let lat = self.latency_snapshot_ms();
        format!(
            "requests={} failed={} batches={} avg_batch={:.1} pad_frac={:.3} \
             avg_exec={:.2}ms avg_queue={:.2}ms lat_p50={:.2}ms \
             lat_p99={:.2}ms",
            reqs_raw,
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            reqs_raw as f64 / batches as f64,
            pad_frac,
            self.exec_us_total.load(Ordering::Relaxed) as f64 / batches as f64
                / 1000.0,
            self.queue_us_total.load(Ordering::Relaxed) as f64 / reqs as f64
                / 1000.0,
            crate::util::stats::percentile_sorted(&lat, 50.0),
            crate::util::stats::percentile_sorted(&lat, 99.0),
        )
    }
}

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub artifact_dir: String,
    pub artifact: String,
    pub batch: usize,
    pub classes: usize,
    pub max_wait: Duration,
    pub workers: usize,
    /// extra inputs appended after (or before) the image batch
    pub extra_inputs: Vec<ExtraInput>,
    /// true: images are the first executable parameter
    pub image_param_first: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: crate::artifact_dir(),
            artifact: "cnn_ideal".into(),
            batch: 128,
            classes: 10,
            max_wait: Duration::from_millis(5),
            workers: 1,
            extra_inputs: Vec::new(),
            image_param_first: true,
        }
    }
}

/// Handle the caller keeps: submit images, await logits.
pub struct Coordinator {
    queue: Arc<SharedQueue<Request>>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    image_len: usize,
    classes: usize,
}

impl Coordinator {
    pub fn start(cfg: CoordinatorConfig, image_len: usize) -> Result<Coordinator> {
        let queue = Arc::new(SharedQueue::new());
        let metrics = Arc::new(Metrics::default());
        let policy = BatchPolicy { max_batch: cfg.batch, max_wait: cfg.max_wait };
        // ready-barrier: surface artifact/compile errors to the caller
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let queue = queue.clone();
            let metrics = metrics.clone();
            let policy = policy.clone();
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                // PJRT state lives and dies on this thread
                let setup = (|| -> Result<_> {
                    let rt = Runtime::new(&cfg.artifact_dir)?;
                    let exe = rt.load(&cfg.artifact)?;
                    let extra: Vec<xla::Literal> = cfg
                        .extra_inputs
                        .iter()
                        .map(|e| e.to_literal())
                        .collect::<Result<_>>()?;
                    Ok((rt, exe, extra))
                })();
                let (_rt, exe, extra) = match setup {
                    Ok(x) => {
                        let _ = ready.send(Ok(()));
                        x
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                let batcher = Batcher::new(policy);
                loop {
                    let Some(reqs) = batcher.collect(&queue) else { break };
                    if reqs.is_empty() {
                        continue;
                    }
                    if let Err(e) = run_batch(&exe, &extra, reqs, cfg.batch,
                                              cfg.classes, cfg.image_param_first,
                                              &metrics) {
                        eprintln!("[coordinator] batch failed: {e:#}");
                    }
                }
            }));
        }
        drop(ready_tx);
        for _ in 0..cfg.workers.max(1) {
            let ready = ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during setup"))
                .and_then(|r| r);
            if let Err(e) = ready {
                // let the workers that did come up exit cleanly
                queue.close();
                return Err(e);
            }
        }
        Ok(Coordinator {
            queue,
            next_id: AtomicU64::new(0),
            metrics,
            workers,
            image_len,
            classes: cfg.classes,
        })
    }

    /// Submit one image; returns a receiver for the response.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        anyhow::ensure!(image.len() == self.image_len, "bad image size");
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue
            .push(Request { id, image, respond: rtx, enqueued: Instant::now() })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        Ok(rrx)
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Stop workers and drain.
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in std::mem::take(&mut self.workers) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // close the queue so workers exit even without an explicit
        // shutdown() (e.g. a panicking test); threads are not joined here
        self.queue.close();
    }
}

/// Exact integer side length of a square HWC image with 3 channels.
/// Float sqrt alone can truncate (e.g. yield 223 for a 224x224 image), so
/// round then verify, and reject non-square inputs with a clear error.
fn image_side(image_len: usize) -> Result<i64> {
    anyhow::ensure!(
        image_len > 0 && image_len % 3 == 0,
        "image length {image_len} is not HWC with 3 channels"
    );
    let pixels = (image_len / 3) as u64;
    let mut s = (pixels as f64).sqrt().round() as u64;
    while s > 0 && s * s > pixels {
        s -= 1;
    }
    while (s + 1) * (s + 1) <= pixels {
        s += 1;
    }
    anyhow::ensure!(
        s * s == pixels,
        "non-square image: {image_len} values = {pixels} pixels/channel"
    );
    Ok(s as i64)
}

fn run_batch(exe: &crate::runtime::Executable, extra: &[xla::Literal],
             reqs: Vec<Request>, batch: usize, classes: usize,
             image_first: bool, metrics: &Metrics) -> Result<()> {
    let n = reqs.len();
    match exec_batch(exe, extra, &reqs, batch, classes, image_first) {
        Ok((logits, exec_us)) => {
            metrics.requests.fetch_add(n as u64, Ordering::Relaxed);
            metrics.batches.fetch_add(1, Ordering::Relaxed);
            metrics
                .padded_slots
                .fetch_add((batch - n) as u64, Ordering::Relaxed);
            metrics.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
            for (i, r) in reqs.into_iter().enumerate() {
                let total_us = r.enqueued.elapsed().as_micros() as u64;
                let queue_us = total_us.saturating_sub(exec_us);
                metrics.queue_us_total.fetch_add(queue_us, Ordering::Relaxed);
                metrics.record_latency_us(total_us);
                let _ = r.respond.send(Response {
                    id: r.id,
                    logits: logits[i * classes..(i + 1) * classes].to_vec(),
                    queue_us,
                    exec_us,
                    batch_size: n,
                    error: None,
                });
            }
            Ok(())
        }
        Err(e) => {
            // don't drop the requests: answer every caller with the cause
            // and count the failures
            metrics.failed.fetch_add(n as u64, Ordering::Relaxed);
            let msg = format!("{e:#}");
            for r in reqs {
                let queue_us = r.enqueued.elapsed().as_micros() as u64;
                let _ = r.respond.send(Response {
                    id: r.id,
                    logits: Vec::new(),
                    queue_us,
                    exec_us: 0,
                    batch_size: n,
                    error: Some(msg.clone()),
                });
            }
            Err(e)
        }
    }
}

/// The fallible half of a batch: assemble, execute, validate.
fn exec_batch(exe: &crate::runtime::Executable, extra: &[xla::Literal],
              reqs: &[Request], batch: usize, classes: usize,
              image_first: bool) -> Result<(Vec<f32>, u64)> {
    let n = reqs.len();
    let image_len = reqs[0].image.len();
    let mut data = Vec::with_capacity(batch * image_len);
    for r in reqs {
        data.extend_from_slice(&r.image);
    }
    // pad the tail by repeating the last image (results discarded)
    for _ in n..batch {
        data.extend_from_slice(&reqs[n - 1].image);
    }
    let side = image_side(image_len)?;
    let images = runtime::lit_f32(&data, &[batch as i64, side, side, 3])?;
    let mut inputs: Vec<&xla::Literal> = Vec::new();
    if image_first {
        inputs.push(&images);
        inputs.extend(extra.iter());
    } else {
        inputs.extend(extra.iter());
        inputs.push(&images);
    }
    let t0 = Instant::now();
    let out = exe.run_refs(&inputs)?;
    let exec_us = t0.elapsed().as_micros() as u64;
    let logits = runtime::to_f32_vec(&out[0])?;
    anyhow::ensure!(logits.len() == batch * classes, "bad logits size");
    Ok((logits, exec_us))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_summary_formats() {
        let m = Metrics::default();
        m.requests.store(10, Ordering::Relaxed);
        m.batches.store(2, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("requests=10"));
        assert!(s.contains("avg_batch=5.0"));
        assert!(s.contains("failed=0"));
    }

    #[test]
    fn metrics_latency_percentiles() {
        let m = Metrics::default();
        // empty window: percentiles report 0 (callers see an idle server)
        assert_eq!(m.latency_percentile_ms(50.0), 0.0);
        for us in [1000u64, 2000, 3000, 4000] {
            m.record_latency_us(us);
        }
        assert!((m.latency_percentile_ms(50.0) - 2.5).abs() < 1e-9);
        assert!((m.latency_percentile_ms(100.0) - 4.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("lat_p50=2.50ms"), "{s}");
        assert!(s.contains("lat_p99="), "{s}");
    }

    #[test]
    fn metrics_latency_window_is_bounded() {
        let m = Metrics::default();
        for us in 0..(LATENCY_WINDOW as u64 + 100) {
            m.record_latency_us(us);
        }
        let w = m.lat_us.lock().unwrap();
        assert_eq!(w.len(), LATENCY_WINDOW);
        // the oldest 100 samples were evicted
        assert_eq!(*w.front().unwrap(), 100);
    }

    #[test]
    fn metrics_pad_frac_zero_when_unserved() {
        // regression: the old max(1) clamp reported a bogus fraction for
        // an idle coordinator
        let m = Metrics::default();
        assert!(m.summary().contains("pad_frac=0.000"), "{}", m.summary());
        m.padded_slots.store(3, Ordering::Relaxed);
        m.requests.store(1, Ordering::Relaxed);
        assert!(m.summary().contains("pad_frac=0.750"), "{}", m.summary());
    }

    #[test]
    fn image_side_is_exact() {
        // the float-truncation regression: 224*224*3 must give 224
        for side in [1u64, 3, 28, 32, 223, 224, 225, 1024] {
            let len = (side * side * 3) as usize;
            assert_eq!(image_side(len).unwrap(), side as i64, "side {side}");
        }
    }

    #[test]
    fn image_side_rejects_bad_shapes() {
        assert!(image_side(0).is_err());
        assert!(image_side(4).is_err()); // not divisible by 3
        assert!(image_side(3 * 5).is_err()); // 5 pixels: not square
        assert!(image_side((224 * 224 - 1) * 3).is_err());
    }

    #[test]
    fn extra_input_literals() {
        let k = ExtraInput::KeyU32(7).to_literal().unwrap();
        assert_eq!(k.element_count(), 2);
        let s = ExtraInput::ScalarF32(255.0).to_literal().unwrap();
        assert_eq!(s.element_count(), 1);
    }
}
