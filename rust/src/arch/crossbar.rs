//! Bit-exact behavioural crossbar model: the Rust golden reference for
//! the Strategy-C dataflow (mirrors kernels/ref.py) and the native
//! implementation of the three accumulation strategies at the
//! dot-product level. Integration tests compare the PJRT-executed HLO
//! artifacts against this.

use super::{bit_slices, quantize_signed, quantize_uniform, sa_unrolled_scale};

/// One dot-product group: a signed 8-bit weight vector down <=128 rows.
#[derive(Debug, Clone)]
pub struct Group {
    /// signed weights, length = rows
    pub w: Vec<i32>,
}

impl Group {
    /// Exact integer dot product.
    pub fn dot(&self, x: &[u32]) -> i64 {
        assert_eq!(x.len(), self.w.len());
        x.iter()
            .zip(&self.w)
            .map(|(xi, wi)| *xi as i64 * *wi as i64)
            .sum()
    }

    /// Per-(cycle, plane) differential partial sums, LSB-first.
    /// Returns `slices x 8` integers.
    pub fn partial_sums(&self, x: &[u32], pd: u32) -> Vec<[i64; 8]> {
        let slices: Vec<Vec<u32>> =
            x.iter().map(|&xi| bit_slices(xi, 8, pd)).collect();
        let n_slices = 8u32.div_ceil(pd) as usize;
        let mut out = vec![[0i64; 8]; n_slices];
        for (row, wi) in self.w.iter().enumerate() {
            let (wp, wn) = (wi.max(&0).unsigned_abs(), (-wi).max(0) as u32);
            for s in 0..n_slices {
                let xs = slices[row][s] as i64;
                for (j, o) in out[s].iter_mut().enumerate() {
                    let bit_p = ((wp >> j) & 1) as i64;
                    let bit_n = ((wn >> j) & 1) as i64;
                    *o += xs * (bit_p - bit_n);
                }
            }
        }
        out
    }

    /// Strategy A: quantize every per-(cycle, BL) partial sum at
    /// `adc_levels`, digitally shift-and-add. Full scale is the array
    /// maximum (Eq. 2's premise). Mirrors model.strategy_a_matmul.
    pub fn strategy_a(&self, x: &[u32], pd: u32, adc_levels: f64,
                      array_rows: u32) -> f64 {
        let fs = array_rows as f64 * (2f64.powi(pd as i32) - 1.0);
        let slices: Vec<Vec<u32>> =
            x.iter().map(|&xi| bit_slices(xi, 8, pd)).collect();
        let n_slices = 8u32.div_ceil(pd) as usize;
        let mut total = 0.0;
        for s in 0..n_slices {
            for j in 0..8 {
                let mut pp = 0.0;
                let mut pn = 0.0;
                for (row, wi) in self.w.iter().enumerate() {
                    let xs = slices[row][s] as f64;
                    let wp = wi.max(&0).unsigned_abs();
                    let wn = (-wi).max(0) as u32;
                    pp += xs * ((wp >> j) & 1) as f64;
                    pn += xs * ((wn >> j) & 1) as f64;
                }
                let qp = quantize_uniform(pp, adc_levels, fs);
                let qn = quantize_uniform(pn, adc_levels, fs);
                total += 2f64.powi((pd as usize * s + j) as i32) * (qp - qn);
            }
        }
        total.round()
    }

    /// Strategy C (ideal): analog accumulation then one signed range-aware
    /// conversion over [-d_max, d_max]. Mirrors model.strategy_c_matmul
    /// without the lumped noise.
    pub fn strategy_c(&self, x: &[u32], pd: u32, adc_levels: f64,
                      d_max: f64) -> f64 {
        let partial = self.partial_sums(x, pd);
        let n_slices = partial.len() as u32;
        let alpha = super::sa_alpha(pd);
        let mut acc = 0.0;
        for p in &partial {
            let s: f64 = p
                .iter()
                .enumerate()
                .map(|(j, v)| 2f64.powi(j as i32) * *v as f64)
                .sum();
            acc = 2f64.powi(-(pd as i32)) * acc + s / alpha;
        }
        let d = acc * sa_unrolled_scale(n_slices, pd);
        quantize_signed(d, adc_levels, d_max).round()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn rand_group(g: &mut crate::util::prop::Gen, rows: usize) -> (Group, Vec<u32>) {
        let w: Vec<i32> = (0..rows)
            .map(|_| g.rng().below(255) as i32 - 127)
            .collect();
        let x: Vec<u32> = (0..rows).map(|_| g.rng().below(256) as u32).collect();
        (Group { w }, x)
    }

    #[test]
    fn partial_sums_reassemble_to_dot() {
        prop::check("partials radix-reassemble to the dot product", 100, |g| {
            let rows = g.usize_in(1, 128);
            let pd = *g.pick(&[1u32, 2, 4, 8]);
            let (grp, x) = rand_group(g, rows);
            let d = grp.dot(&x);
            let partial = grp.partial_sums(&x, pd);
            let mut back = 0i64;
            for (s, p) in partial.iter().enumerate() {
                for (j, v) in p.iter().enumerate() {
                    back += (1i64 << (pd as usize * s + j)) * v;
                }
            }
            crate::prop_assert!(back == d, "{} != {}", back, d);
            Ok(())
        });
    }

    #[test]
    fn strategy_c_exact_at_full_resolution() {
        prop::check("strategy C with generous ADC is exact", 60, |g| {
            let rows = g.usize_in(1, 128);
            let pd = *g.pick(&[1u32, 2, 4]);
            let (grp, x) = rand_group(g, rows);
            let d = grp.dot(&x) as f64;
            // 20-bit converter: quantization error < 0.5 in D units
            let d_max = 128.0 * 255.0 * 127.0;
            let got = grp.strategy_c(&x, pd, (1u64 << 22) as f64 - 1.0, d_max);
            crate::prop_assert!(
                (got - d).abs() <= (d.abs() * 1e-5).max(8.0),
                "{} vs {}", got, d
            );
            Ok(())
        });
    }

    #[test]
    fn strategy_a_exact_at_eq2_resolution() {
        // Eq. 2: at full BL resolution, per-conversion quantization is
        // lossless, so strategy A reproduces the exact dot product
        prop::check("strategy A at Eq.2 bound is exact", 60, |g| {
            let rows = g.usize_in(1, 128);
            let pd = *g.pick(&[1u32, 2]);
            let (grp, x) = rand_group(g, rows);
            let d = grp.dot(&x) as f64;
            let fs_levels = 128.0 * (2f64.powi(pd as i32) - 1.0);
            let got = grp.strategy_a(&x, pd, fs_levels, 128);
            crate::prop_assert!((got - d).abs() < 0.5, "{} vs {}", got, d);
            Ok(())
        });
    }

    #[test]
    fn strategy_a_degrades_at_low_resolution() {
        let mut g = crate::util::prop::Gen::new(5);
        let (grp, x) = rand_group(&mut g, 128);
        let d = grp.dot(&x) as f64;
        let err_hi = (grp.strategy_a(&x, 1, 255.0, 128) - d).abs();
        let err_lo = (grp.strategy_a(&x, 1, 15.0, 128) - d).abs();
        assert!(err_lo > err_hi, "lo {err_lo} hi {err_hi}");
    }
}
