//! Behavioural circuit component models.
//!
//! These are the Rust twins of the Python voltage-domain models: bit-exact
//! integer crossbar VMM, bit slicing, DAC/ADC behaviour, the NNS+A
//! recursion and the S/H loop. The simulator uses the *counting* models in
//! `energy/`; these behavioural models back the Rust-side unit tests,
//! property tests and the native (non-PJRT) golden reference the
//! integration tests compare PJRT outputs against.

pub mod crossbar;
pub mod noc;

use crate::util::rng::Pcg;

/// Voltage rail and analog range (matching python/compile/common.py).
pub const VDD: f64 = 1.2;
pub const V_RANGE: f64 = 0.5;

/// Split an unsigned value into LSB-first bit-slices of `pd` bits.
pub fn bit_slices(x: u32, pi: u32, pd: u32) -> Vec<u32> {
    let n = pi.div_ceil(pd);
    (0..n).map(|i| (x >> (pd * i)) & ((1 << pd) - 1)).collect()
}

/// Ideal uniform quantizer over [0, full_scale] with `levels` steps,
/// returning the dequantized value.
pub fn quantize_uniform(v: f64, levels: f64, full_scale: f64) -> f64 {
    let v = v.clamp(0.0, full_scale);
    (v / full_scale * levels).round() / levels * full_scale
}

/// Signed uniform quantizer over [-fs, fs].
pub fn quantize_signed(v: f64, levels: f64, fs: f64) -> f64 {
    let v = v.clamp(-fs, fs);
    (v / fs * levels).round() / levels * fs
}

/// The NNS+A cyclic recursion constants (see common.py's derivation):
/// alpha = 2^pd (2^8 - 1) / (2^pd - 1).
pub fn sa_alpha(pd: u32) -> f64 {
    2f64.powi(pd as i32) * 255.0 / (2f64.powi(pd as i32) - 1.0)
}

/// K such that the final accumulator equals D / K.
pub fn sa_unrolled_scale(n_slices: u32, pd: u32) -> f64 {
    sa_alpha(pd) * 2f64.powi((pd * (n_slices - 1)) as i32)
}

/// An ideal DAC: code -> voltage in [0, V_RANGE].
#[derive(Debug, Clone, Copy)]
pub struct Dac {
    pub bits: u32,
}

impl Dac {
    pub fn convert(&self, code: u32) -> f64 {
        let max = (1u32 << self.bits) - 1;
        code.min(max) as f64 / max as f64 * V_RANGE
    }
}

/// A behavioural SAR ADC with optional input-referred noise.
#[derive(Debug, Clone, Copy)]
pub struct Adc {
    pub bits: u32,
    pub full_scale: f64,
    pub noise_sigma: f64,
}

impl Adc {
    pub fn convert(&self, v: f64, rng: &mut Pcg) -> u32 {
        let v = v + self.noise_sigma * rng.normal();
        let levels = (1u64 << self.bits) as f64 - 1.0;
        (v.clamp(0.0, self.full_scale) / self.full_scale * levels).round()
            as u32
    }
}

/// Sample-and-hold with incomplete charge transfer + thermal noise
/// (§5.3.1's non-idealities).
#[derive(Debug, Clone, Copy)]
pub struct SampleHold {
    /// fractional charge lost per transfer
    pub loss: f64,
    /// thermal noise, volts rms
    pub sigma_v: f64,
}

impl SampleHold {
    pub fn transfer(&self, v: f64, rng: &mut Pcg) -> f64 {
        v * (1.0 - self.loss) + self.sigma_v * rng.normal()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_slices_reassemble() {
        for pd in [1u32, 2, 4, 8] {
            for x in [0u32, 1, 37, 200, 255] {
                let s = bit_slices(x, 8, pd);
                let back: u32 = s
                    .iter()
                    .enumerate()
                    .map(|(i, v)| v << (pd * i as u32))
                    .sum();
                assert_eq!(back, x, "pd={pd} x={x}");
            }
        }
    }

    #[test]
    fn quantizer_idempotent() {
        let q = quantize_uniform(0.3337, 255.0, 1.0);
        assert_eq!(quantize_uniform(q, 255.0, 1.0), q);
    }

    #[test]
    fn sa_scale_matches_python() {
        // spot values mirrored from the python tests
        assert!((sa_alpha(4) - 272.0).abs() < 1e-9);
        assert!((sa_alpha(1) - 510.0).abs() < 1e-9);
        assert!((sa_unrolled_scale(2, 4) - 272.0 * 16.0).abs() < 1e-9);
    }

    #[test]
    fn adc_dac_round_trip() {
        let dac = Dac { bits: 8 };
        let adc = Adc { bits: 8, full_scale: V_RANGE, noise_sigma: 0.0 };
        let mut rng = Pcg::new(0);
        for code in [0u32, 1, 100, 254, 255] {
            let v = dac.convert(code);
            assert_eq!(adc.convert(v, &mut rng), code);
        }
    }

    #[test]
    fn sample_hold_loss() {
        let sh = SampleHold { loss: 0.01, sigma_v: 0.0 };
        let mut rng = Pcg::new(1);
        assert!((sh.transfer(1.0, &mut rng) - 0.99).abs() < 1e-12);
    }
}
