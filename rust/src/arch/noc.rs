//! Concentrated-mesh NoC model (§5.2.4): routers shared by
//! `concentration` adjacent tiles, XY routing, per-hop energy/latency.
//!
//! The simulator charges average-hop energy; this module provides the
//! exact router grid, XY routes, and a contention-free latency model the
//! property tests exercise (routing reachability / determinism), plus
//! the per-flit energy used by `sim/`. The contention-aware queueing
//! refinement lives in `event::noc`, which layers per-port occupancy on
//! the same XY routes and reduces to [`CMesh::transfer_latency_ns`]
//! exactly when no two transfers share a port.
//!
//! **Zero-hop convention:** tiles concentrated on the same router still
//! cross that router's local crossbar, so *both* `transfer_energy` and
//! `transfer_latency_ns` clamp the hop count to at least 1. A transfer
//! is never free, even to a neighbouring tile.

use crate::energy::constants as k;

#[derive(Debug, Clone)]
pub struct CMesh {
    pub tiles: u32,
    pub concentration: u32,
    /// routers per side of the (square-ish) mesh
    pub side: u32,
}

impl CMesh {
    pub fn new(tiles: u32, concentration: u32) -> CMesh {
        let routers = tiles.div_ceil(concentration).max(1);
        let side = (routers as f64).sqrt().ceil() as u32;
        CMesh { tiles, concentration, side }
    }

    pub fn router_of(&self, tile: u32) -> (u32, u32) {
        let r = tile / self.concentration;
        (r % self.side, r / self.side)
    }

    /// Manhattan hop count of the XY route between two tiles.
    pub fn hops(&self, from: u32, to: u32) -> u32 {
        let (x0, y0) = self.router_of(from);
        let (x1, y1) = self.router_of(to);
        x0.abs_diff(x1) + y0.abs_diff(y1)
    }

    /// The XY route as a list of routers (inclusive of both endpoints).
    pub fn route(&self, from: u32, to: u32) -> Vec<(u32, u32)> {
        let mut path = Vec::new();
        self.route_into(from, to, &mut path);
        path
    }

    /// [`CMesh::route`] into a caller-owned buffer (cleared first), so
    /// per-transfer hot paths can reuse one allocation.
    pub fn route_into(&self, from: u32, to: u32, out: &mut Vec<(u32, u32)>) {
        out.clear();
        let (mut x, mut y) = self.router_of(from);
        let (x1, y1) = self.router_of(to);
        out.push((x, y));
        while x != x1 {
            x = if x < x1 { x + 1 } else { x - 1 };
            out.push((x, y));
        }
        while y != y1 {
            y = if y < y1 { y + 1 } else { y - 1 };
            out.push((x, y));
        }
    }

    /// Routers actually occupied by at least one tile (the grid's last
    /// row may be partial when `tiles / concentration < side²`).
    pub fn occupied_routers(&self) -> u32 {
        self.tiles.div_ceil(self.concentration).max(1)
    }

    /// Exact average hop count over all ordered tile pairs (including
    /// same-tile pairs, which contribute 0 hops).
    ///
    /// The old closed form `2(s²−1)/(3s)` assumes every slot of the s×s
    /// router grid is occupied; with a partial last row (e.g. 12 routers
    /// on a 4-wide mesh) it overestimates. Here we weight each router
    /// pair by the number of tiles it concentrates, which is exact for
    /// any tile count — O(R²) over occupied routers, cheap at the tile
    /// counts the simulator uses.
    pub fn average_hops(&self) -> f64 {
        if self.tiles == 0 {
            return 0.0;
        }
        let routers = self.occupied_routers();
        // tiles per occupied router: `concentration`, except the last
        // router which holds the remainder
        let tiles_on = |r: u32| -> u64 {
            let lo = r as u64 * self.concentration as u64;
            let hi = (lo + self.concentration as u64).min(self.tiles as u64);
            hi - lo
        };
        let coord = |r: u32| (r % self.side, r / self.side);
        let mut weighted = 0u128;
        for a in 0..routers {
            let wa = tiles_on(a);
            if wa == 0 {
                continue;
            }
            for b in 0..routers {
                let (ax, ay) = coord(a);
                let (bx, by) = coord(b);
                let h = (ax.abs_diff(bx) + ay.abs_diff(by)) as u128;
                weighted += wa as u128 * tiles_on(b) as u128 * h;
            }
        }
        let pairs = self.tiles as u128 * self.tiles as u128;
        weighted as f64 / pairs as f64
    }

    /// Energy to move `bytes` across `hops` routers (min 1: see the
    /// zero-hop convention in the module docs).
    pub fn transfer_energy(&self, bytes: u64, hops: u32) -> f64 {
        bytes as f64 * k::NOC_E_BYTE * (hops.max(1)) as f64
    }

    /// Contention-free transfer latency in ns: 1 cycle per hop at the
    /// 1 GHz NoC clock — clamped to at least one router traversal, the
    /// same zero-hop convention `transfer_energy` uses — plus
    /// serialization at 32 B/cycle (at least one flit).
    pub fn transfer_latency_ns(&self, bytes: u64, hops: u32) -> f64 {
        hops.max(1) as f64 + bytes.div_ceil(32).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn routes_reach_destination() {
        prop::check("xy route ends at the destination router", 100, |g| {
            let tiles = g.usize_in(1, 512) as u32;
            let conc = *g.pick(&[1u32, 2, 4, 8]);
            let mesh = CMesh::new(tiles, conc);
            let a = g.usize_in(0, tiles as usize - 1) as u32;
            let b = g.usize_in(0, tiles as usize - 1) as u32;
            let path = mesh.route(a, b);
            crate::prop_assert!(*path.first().unwrap() == mesh.router_of(a),
                                "bad start");
            crate::prop_assert!(*path.last().unwrap() == mesh.router_of(b),
                                "bad end");
            crate::prop_assert!(
                path.len() as u32 == mesh.hops(a, b) + 1,
                "path len {} vs hops {}", path.len(), mesh.hops(a, b)
            );
            // adjacent routers differ by exactly one coordinate step
            for w in path.windows(2) {
                let d = w[0].0.abs_diff(w[1].0) + w[0].1.abs_diff(w[1].1);
                crate::prop_assert!(d == 1, "non-adjacent step");
            }
            Ok(())
        });
    }

    #[test]
    fn routing_is_deterministic_and_symmetric_in_hops() {
        prop::check("hops symmetric", 100, |g| {
            let mesh = CMesh::new(280, 4);
            let a = g.usize_in(0, 279) as u32;
            let b = g.usize_in(0, 279) as u32;
            crate::prop_assert!(mesh.hops(a, b) == mesh.hops(b, a), "asym");
            crate::prop_assert!(mesh.route(a, b) == mesh.route(a, b), "nondet");
            Ok(())
        });
    }

    #[test]
    fn same_router_zero_hops() {
        let mesh = CMesh::new(280, 4);
        assert_eq!(mesh.hops(0, 3), 0); // concentrated: 4 tiles share r0
        assert!(mesh.hops(0, 4) >= 1);
    }

    #[test]
    fn average_hops_reasonable() {
        let mesh = CMesh::new(280, 4); // 70 routers -> side 9
        let avg = mesh.average_hops();
        assert!(avg > 2.0 && avg < 9.0, "avg {avg}");
    }

    #[test]
    fn average_hops_matches_brute_force() {
        // exact mean over ALL ordered tile pairs, incl. partial router
        // grids and a partially-filled last router
        for (tiles, conc) in
            [(280u32, 4u32), (48, 4), (46, 4), (12, 1), (1, 1), (7, 2),
             (100, 8), (33, 1), (512, 8)]
        {
            let mesh = CMesh::new(tiles, conc);
            let mut sum = 0u64;
            for a in 0..tiles {
                for b in 0..tiles {
                    sum += mesh.hops(a, b) as u64;
                }
            }
            let brute = sum as f64 / (tiles as f64 * tiles as f64);
            let fast = mesh.average_hops();
            assert!(
                (fast - brute).abs() < 1e-9,
                "tiles {tiles} conc {conc}: fast {fast} vs brute {brute}"
            );
        }
    }

    #[test]
    fn partial_grid_average_below_old_closed_form() {
        // 12 routers on a 4-wide mesh (3 of 4 rows occupied): the old
        // closed form 2(s²−1)/(3s) assumed the full 4x4 grid and
        // overestimated
        let mesh = CMesh::new(48, 4);
        assert_eq!(mesh.occupied_routers(), 12);
        assert_eq!(mesh.side, 4);
        let closed_form = 2.0 * (16.0 - 1.0) / (3.0 * 4.0);
        assert!(
            mesh.average_hops() < closed_form - 0.1,
            "exact {} vs closed form {closed_form}",
            mesh.average_hops()
        );
    }

    #[test]
    fn zero_hop_convention_unified() {
        let mesh = CMesh::new(280, 4);
        assert_eq!(mesh.hops(0, 3), 0); // tiles 0..3 share router 0
        // both energy and latency charge exactly one router traversal
        // for a local transfer — a 0-hop transfer costs the same as a
        // 1-hop one, and never 0
        assert!(mesh.transfer_energy(64, 0) > 0.0);
        assert!(
            (mesh.transfer_energy(64, 0) - mesh.transfer_energy(64, 1)).abs()
                < 1e-30
        );
        assert!(
            (mesh.transfer_latency_ns(64, 0) - mesh.transfer_latency_ns(64, 1))
                .abs()
                < 1e-12
        );
        // 64 B = 2 flits, 1 router traversal -> 3 cycles at 1 GHz
        assert!((mesh.transfer_latency_ns(64, 0) - 3.0).abs() < 1e-12);
        // two real hops cost strictly more than the local clamp
        assert!(mesh.transfer_latency_ns(64, 2) > mesh.transfer_latency_ns(64, 0));
        assert!(mesh.transfer_energy(64, 2) > mesh.transfer_energy(64, 0));
        // zero bytes still serialize one flit
        assert!((mesh.transfer_latency_ns(0, 0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn route_len_matches_hops_plus_one_on_partial_grids() {
        // the routing property the event NoC relies on, exercised across
        // meshes whose last router row is partial
        prop::check("route(a,b).len() == hops(a,b) + 1", 200, |g| {
            let conc = *g.pick(&[1u32, 2, 4, 8]);
            let tiles = g.usize_in(1, 300) as u32;
            let mesh = CMesh::new(tiles, conc);
            let a = g.usize_in(0, tiles as usize - 1) as u32;
            let b = g.usize_in(0, tiles as usize - 1) as u32;
            crate::prop_assert!(
                mesh.route(a, b).len() as u32 == mesh.hops(a, b) + 1,
                "route len {} vs hops {}", mesh.route(a, b).len(),
                mesh.hops(a, b)
            );
            Ok(())
        });
    }
}
