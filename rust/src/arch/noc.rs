//! Concentrated-mesh NoC model (§5.2.4): routers shared by
//! `concentration` adjacent tiles, XY routing, per-hop energy/latency.
//!
//! The simulator charges average-hop energy; this module provides the
//! exact router grid, XY routes, and a contention-free latency model the
//! property tests exercise (routing reachability / determinism), plus
//! the per-flit energy used by `sim/`.

use crate::energy::constants as k;

#[derive(Debug, Clone)]
pub struct CMesh {
    pub tiles: u32,
    pub concentration: u32,
    /// routers per side of the (square-ish) mesh
    pub side: u32,
}

impl CMesh {
    pub fn new(tiles: u32, concentration: u32) -> CMesh {
        let routers = tiles.div_ceil(concentration).max(1);
        let side = (routers as f64).sqrt().ceil() as u32;
        CMesh { tiles, concentration, side }
    }

    pub fn router_of(&self, tile: u32) -> (u32, u32) {
        let r = tile / self.concentration;
        (r % self.side, r / self.side)
    }

    /// Manhattan hop count of the XY route between two tiles.
    pub fn hops(&self, from: u32, to: u32) -> u32 {
        let (x0, y0) = self.router_of(from);
        let (x1, y1) = self.router_of(to);
        x0.abs_diff(x1) + y0.abs_diff(y1)
    }

    /// The XY route as a list of routers (inclusive of both endpoints).
    pub fn route(&self, from: u32, to: u32) -> Vec<(u32, u32)> {
        let (mut x, mut y) = self.router_of(from);
        let (x1, y1) = self.router_of(to);
        let mut path = vec![(x, y)];
        while x != x1 {
            x = if x < x1 { x + 1 } else { x - 1 };
            path.push((x, y));
        }
        while y != y1 {
            y = if y < y1 { y + 1 } else { y - 1 };
            path.push((x, y));
        }
        path
    }

    /// Average hop count over uniform-random tile pairs (closed form for
    /// a side-`s` mesh: 2 * (s^2 - 1) / (3 s) per dimension pair).
    pub fn average_hops(&self) -> f64 {
        let s = self.side as f64;
        2.0 * (s * s - 1.0) / (3.0 * s)
    }

    /// Energy to move `bytes` across `hops` routers.
    pub fn transfer_energy(&self, bytes: u64, hops: u32) -> f64 {
        bytes as f64 * k::NOC_E_BYTE * (hops.max(1)) as f64
    }

    /// Contention-free transfer latency in ns (1 cycle/hop at 1 GHz +
    /// serialization at 32 B/cycle).
    pub fn transfer_latency_ns(&self, bytes: u64, hops: u32) -> f64 {
        hops as f64 + bytes.div_ceil(32) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn routes_reach_destination() {
        prop::check("xy route ends at the destination router", 100, |g| {
            let tiles = g.usize_in(1, 512) as u32;
            let conc = *g.pick(&[1u32, 2, 4, 8]);
            let mesh = CMesh::new(tiles, conc);
            let a = g.usize_in(0, tiles as usize - 1) as u32;
            let b = g.usize_in(0, tiles as usize - 1) as u32;
            let path = mesh.route(a, b);
            crate::prop_assert!(*path.first().unwrap() == mesh.router_of(a),
                                "bad start");
            crate::prop_assert!(*path.last().unwrap() == mesh.router_of(b),
                                "bad end");
            crate::prop_assert!(
                path.len() as u32 == mesh.hops(a, b) + 1,
                "path len {} vs hops {}", path.len(), mesh.hops(a, b)
            );
            // adjacent routers differ by exactly one coordinate step
            for w in path.windows(2) {
                let d = w[0].0.abs_diff(w[1].0) + w[0].1.abs_diff(w[1].1);
                crate::prop_assert!(d == 1, "non-adjacent step");
            }
            Ok(())
        });
    }

    #[test]
    fn routing_is_deterministic_and_symmetric_in_hops() {
        prop::check("hops symmetric", 100, |g| {
            let mesh = CMesh::new(280, 4);
            let a = g.usize_in(0, 279) as u32;
            let b = g.usize_in(0, 279) as u32;
            crate::prop_assert!(mesh.hops(a, b) == mesh.hops(b, a), "asym");
            crate::prop_assert!(mesh.route(a, b) == mesh.route(a, b), "nondet");
            Ok(())
        });
    }

    #[test]
    fn same_router_zero_hops() {
        let mesh = CMesh::new(280, 4);
        assert_eq!(mesh.hops(0, 3), 0); // concentrated: 4 tiles share r0
        assert!(mesh.hops(0, 4) >= 1);
    }

    #[test]
    fn average_hops_reasonable() {
        let mesh = CMesh::new(280, 4); // 70 routers -> side 9
        let avg = mesh.average_hops();
        assert!(avg > 2.0 && avg < 9.0, "avg {avg}");
    }
}
