//! PIM + NPU hybrid deployment: deterministic per-layer placement
//! search between the Neural-PIM crossbar chip and the all-digital
//! [`model::archs::NpuModel`](crate::model::archs::NpuModel).
//!
//! The paper's chip wins on dense crossbar-friendly layers, where the
//! analog accumulation amortizes conversions over long K dimensions —
//! but depthwise, short-K and low-reuse layers pay the crossbar's fixed
//! per-position costs for little reuse, and a plain digital MAC array
//! prices them lower. This subsystem searches the `2^n` per-layer
//! splits of a network between the two chips:
//!
//! - [`search::LayerTable`] reads per-layer energy and stage time off
//!   the two **pure** memoized cost tables (`model::network_cost`) —
//!   each side priced under its own deployment (its own mapping,
//!   replication, chip count), so a hybrid is assembled from real
//!   deployable columns rather than re-mapped per candidate.
//! - [`search::run`] minimizes EDP (energy x bottleneck stage time)
//!   exhaustively for networks of ≤ [`search::EXHAUSTIVE_MAX`] layers,
//!   and by seeded hill-climb or epsilon-greedy bandit above that. All
//!   strategies evaluate both pure extremes, so the result is never
//!   worse than all-PIM or all-NPU.
//! - [`optimize`] packages the winner — placement, EDP win, per-layer
//!   split, search-effort counters — for the `offload` scenario, and
//!   routes the chosen placement back through
//!   [`model::network_cost_hybrid`] and
//!   [`event::hybrid_service_profile`](crate::event::hybrid_service_profile)
//!   so the reported deployment is the one the rest of the toolchain
//!   (event pipeline, serving layer) would execute.
//!
//! Determinism contract: the search derives all randomness from
//! `Pcg::fork` under `FORK_NS_OFFLOAD`, fans fixed work decompositions
//! over `util::pool`, and reduces in index order — byte-identical
//! results at any `--threads`, pinned by the integration suite.

pub mod search;

pub use search::{LayerTable, SearchOutcome, Strategy, STRATEGY_CHOICES};

use crate::config::{AcceleratorConfig, Architecture};
use crate::event;
use crate::mapping::Placement;
use crate::model;
use crate::workloads::Network;

/// The NPU side's headline parameter block (defined next to its cost
/// model; re-exported here as part of the subsystem's surface).
pub use crate::model::archs::NpuCost;

/// Energy/delay/EDP of one deployment (pure or hybrid), evaluated
/// through the same [`LayerTable`] arithmetic so the three compare
/// exactly (no float-reassociation slack between them).
#[derive(Debug, Clone, Copy)]
pub struct DeployCost {
    pub energy_j: f64,
    /// steady-state bottleneck stage time, s
    pub delay_s: f64,
    /// energy-delay product, J·s
    pub edp: f64,
    /// chips holding one copy of the deployment's weights
    pub chips: u64,
}

/// One layer's row of the placement report.
#[derive(Debug, Clone)]
pub struct LayerChoice {
    pub name: String,
    /// layer energy priced on each side, J
    pub pim_e: f64,
    pub npu_e: f64,
    pub placement: Placement,
}

/// Everything the `offload` scenario reports for one network.
#[derive(Debug, Clone)]
pub struct OffloadReport {
    pub network: String,
    /// the strategy that ran (`auto` resolved to its concrete choice)
    pub strategy: &'static str,
    pub placement: Vec<Placement>,
    pub hybrid: DeployCost,
    pub all_pim: DeployCost,
    pub all_npu: DeployCost,
    pub layers: Vec<LayerChoice>,
    /// placements evaluated / strictly-improving moves accepted
    pub evals: u64,
    pub improved: u64,
    /// headline parameters of the NPU side
    pub npu: NpuCost,
}

impl OffloadReport {
    /// Layers the search moved onto the NPU.
    pub fn npu_layers(&self) -> usize {
        self.placement.iter().filter(|p| p.is_npu()).count()
    }

    /// EDP of the better pure extreme — the bar the hybrid must meet.
    pub fn best_pure_edp(&self) -> f64 {
        self.all_pim.edp.min(self.all_npu.edp)
    }

    /// Hybrid EDP improvement over the better pure extreme, as a
    /// fraction in `[0, 1)` (0 when a pure deployment is optimal).
    pub fn edp_win(&self) -> f64 {
        let floor = self.best_pure_edp();
        if floor <= 0.0 {
            return 0.0;
        }
        (1.0 - self.hybrid.edp / floor).max(0.0)
    }
}

/// The default NPU side of a hybrid: the registered
/// `Architecture::DigitalNpu` chip (iso-organization with Neural-PIM).
pub fn default_npu_config() -> AcceleratorConfig {
    AcceleratorConfig::for_arch(Architecture::DigitalNpu)
}

fn deploy(table: &LayerTable, pl: &[bool], chips: u64) -> DeployCost {
    let (e, d_ps, edp) = table.eval(pl);
    DeployCost { energy_j: e, delay_s: d_ps as f64 * 1e-12, edp, chips }
}

/// Search `net`'s placement space and assemble the full report.
/// Deterministic per `(net, cfg_pim, cfg_npu, strategy, seed)`.
pub fn optimize(net: &Network, cfg_pim: &AcceleratorConfig,
                cfg_npu: &AcceleratorConfig, strategy: Strategy,
                seed: u64) -> OffloadReport {
    let pim = model::network_cost(net, cfg_pim);
    let npu = model::network_cost(net, cfg_npu);
    let table = LayerTable::build(cfg_pim, &pim, cfg_npu, &npu);
    let out = search::run(&table, strategy, seed);

    let n = table.len();
    // the winning placement as the rest of the toolchain would run it:
    // memoized hybrid table (chip count) + hybrid service profile
    let hybrid_nc =
        model::network_cost_hybrid(net, cfg_pim, cfg_npu, &out.placement);
    let sp = event::hybrid_service_profile(cfg_pim, &pim, cfg_npu, &npu,
                                           &out.placement);
    debug_assert_eq!(sp.bottleneck_ps(), out.delay_ps,
                     "search table and hybrid profile disagree");
    let hybrid = DeployCost {
        energy_j: out.energy_j,
        delay_s: out.delay_ps as f64 * 1e-12,
        edp: out.edp,
        chips: hybrid_nc.mapping.chips,
    };
    let all_pim = deploy(&table, &vec![false; n], pim.mapping.chips);
    let all_npu = deploy(&table, &vec![true; n], npu.mapping.chips);

    let layers = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| LayerChoice {
            name: l.name.clone(),
            pim_e: table.pim_e[i],
            npu_e: table.npu_e[i],
            placement: out.placement[i],
        })
        .collect();

    OffloadReport {
        network: net.name.to_string(),
        strategy: out.strategy,
        placement: out.placement,
        hybrid,
        all_pim,
        all_npu,
        layers,
        evals: out.evals,
        improved: out.improved,
        npu: NpuCost::of(cfg_npu),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn hybrid_never_loses_to_a_pure_extreme() {
        let net = workloads::alexnet();
        let r = optimize(&net, &AcceleratorConfig::neural_pim(),
                         &default_npu_config(), Strategy::Auto, 42);
        assert_eq!(r.strategy, "exhaustive"); // 8 layers -> auto
        assert!(r.hybrid.edp <= r.best_pure_edp() * (1.0 + 1e-12),
                "hybrid {} > floor {}", r.hybrid.edp, r.best_pure_edp());
        assert_eq!(r.placement.len(), net.layers.len());
        assert_eq!(r.layers.len(), net.layers.len());
        assert!(r.evals >= 1 << net.layers.len());
    }

    #[test]
    fn vgg16_strictly_beats_both_extremes() {
        // the calibration anchor: VGG-16's conv1_1 (K = 27) is cheaper
        // on the NPU while the deep dense stack stays on PIM
        let net = workloads::vgg16();
        let r = optimize(&net, &AcceleratorConfig::neural_pim(),
                         &default_npu_config(), Strategy::Auto, 42);
        assert!(r.hybrid.edp < r.best_pure_edp(),
                "expected a strict hybrid win on VGG-16");
        assert!(r.npu_layers() >= 1);
        assert!(r.edp_win() > 0.0);
        assert_eq!(r.improved, 1);
    }

    #[test]
    fn report_costs_are_consistent() {
        let net = workloads::synthetic_cnn();
        let r = optimize(&net, &AcceleratorConfig::neural_pim(),
                         &default_npu_config(), Strategy::Exhaustive, 42);
        for c in [&r.hybrid, &r.all_pim, &r.all_npu] {
            assert!(c.energy_j > 0.0 && c.delay_s > 0.0);
            let edp = c.energy_j * c.delay_s;
            assert!((c.edp - edp).abs() <= edp * 1e-12);
            assert!(c.chips >= 1);
        }
        assert!(r.npu.tops_peak > 0.0);
        assert!(r.npu.fill_drain_ns > 0.0);
    }
}
