//! Deterministic per-layer placement search over a precomputed
//! [`LayerTable`].
//!
//! Three strategies behind one [`run`] entry point:
//!
//! - **Exhaustive** — every `2^n` placement of an `n ≤`
//!   [`EXHAUSTIVE_MAX`]-layer network, fanned over `util::pool` in
//!   fixed-size mask chunks (the chunk list never depends on the thread
//!   count, and chunk minima reduce in index order, so the winner is
//!   bit-identical at any `--threads`). Ties break to the lowest mask.
//! - **Hill-climb** — index-order strictly-improving single-flip passes
//!   to a local optimum, from the two pure extremes plus
//!   [`HILL_RESTARTS`] seeded random starts.
//! - **Bandit** — [`BANDIT_ARMS`] epsilon-greedy instances treating
//!   layers as arms (reward: the EDP drop when that layer's side last
//!   flipped), each seeded from the better pure extreme.
//!
//! Every strategy evaluates both pure extremes, so the returned
//! placement's EDP is `<= min(all-PIM, all-NPU)` by construction. All
//! randomness derives from `Pcg::fork` under
//! [`rng::FORK_NS_OFFLOAD`](crate::util::rng::FORK_NS_OFFLOAD) with
//! restart/arm-local indices, so results are bit-identical at any
//! thread count and reproducible from the seed alone.

use crate::config::AcceleratorConfig;
use crate::event;
use crate::mapping::Placement;
use crate::model::NetworkCost;
use crate::util::pool;
use crate::util::rng::{fork_idx, Pcg, FORK_NS_OFFLOAD};
use anyhow::{bail, Result};

/// Largest network the exhaustive strategy accepts (2^16 = 65 536
/// placements; `auto` falls back to hill-climb above this).
pub const EXHAUSTIVE_MAX: usize = 16;

/// Seeded random restarts the hill-climb adds to the two pure extremes.
pub const HILL_RESTARTS: u64 = 6;

/// Independent epsilon-greedy instances the bandit strategy runs.
pub const BANDIT_ARMS: u64 = 4;

/// Exploration rate of the bandit's epsilon-greedy arm selection.
const BANDIT_EPSILON: f64 = 0.2;

/// Bandit steps per layer (each instance runs `n x` this many flips).
const BANDIT_STEPS_PER_LAYER: u64 = 48;

/// Masks per exhaustive pool item — fixed, so the work decomposition
/// (and therefore the reduce order) never depends on `--threads`.
const MASK_CHUNK: u64 = 4096;

/// Placement-search strategy, as spelled by `--search`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// exhaustive when the network fits, hill-climb otherwise
    Auto,
    Exhaustive,
    HillClimb,
    Bandit,
}

/// The `--search` spellings, in help order (`auto` first: the default).
pub const STRATEGY_CHOICES: [&str; 4] =
    ["auto", "exhaustive", "hillclimb", "bandit"];

impl Strategy {
    pub fn parse(s: &str) -> Result<Strategy> {
        match s {
            "auto" => Ok(Strategy::Auto),
            "exhaustive" => Ok(Strategy::Exhaustive),
            "hillclimb" => Ok(Strategy::HillClimb),
            "bandit" => Ok(Strategy::Bandit),
            other => bail!("unknown search strategy '{other}' (expected \
                            one of: {})", STRATEGY_CHOICES.join(", ")),
        }
    }

    /// Resolve `Auto` against the network size.
    fn resolve(self, n_layers: usize) -> Strategy {
        match self {
            Strategy::Auto if n_layers <= EXHAUSTIVE_MAX => {
                Strategy::Exhaustive
            }
            Strategy::Auto => Strategy::HillClimb,
            s => s,
        }
    }

    fn name(self) -> &'static str {
        match self {
            Strategy::Auto => "auto",
            Strategy::Exhaustive => "exhaustive",
            Strategy::HillClimb => "hillclimb",
            Strategy::Bandit => "bandit",
        }
    }
}

/// The search's working set: per-layer energy and stage time on each
/// side, read once from the two **pure** memoized cost tables. The
/// search evaluates thousands of placements against these vectors
/// without touching the memo cache (whose LRU would thrash on 2^16
/// distinct hybrid keys); only the final winner goes back through
/// `model::network_cost_hybrid`.
#[derive(Debug, Clone)]
pub struct LayerTable {
    /// per-layer energy on the PIM side, J (full breakdown total)
    pub pim_e: Vec<f64>,
    /// per-layer energy on the NPU side, J
    pub npu_e: Vec<f64>,
    /// per-layer pipeline stage time on the PIM side, ps
    pub pim_ps: Vec<u64>,
    /// per-layer pipeline stage time on the NPU side, ps
    pub npu_ps: Vec<u64>,
}

impl LayerTable {
    /// Read the table off the two pure cost tables and their service
    /// profiles (the exact numbers `model::network_cost_hybrid` and
    /// `event::hybrid_service_profile` assemble per placement).
    pub fn build(cfg_pim: &AcceleratorConfig, pim: &NetworkCost,
                 cfg_npu: &AcceleratorConfig, npu: &NetworkCost)
                 -> LayerTable {
        assert_eq!(pim.layers.len(), npu.layers.len(),
                   "both sides must price the same network");
        let sp_pim = event::service_profile(cfg_pim, pim);
        let sp_npu = event::service_profile(cfg_npu, npu);
        LayerTable {
            pim_e: pim.layers.iter().map(|c| c.energy.total()).collect(),
            npu_e: npu.layers.iter().map(|c| c.energy.total()).collect(),
            pim_ps: sp_pim.stage_ps,
            npu_ps: sp_npu.stage_ps,
        }
    }

    pub fn len(&self) -> usize {
        self.pim_e.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pim_e.is_empty()
    }

    /// Energy (J), steady-state delay (ps, the bottleneck stage, ≥ 1),
    /// and EDP (J·s) of one placement (`true` = NPU). Fixed
    /// accumulation order: layer 0 first, so the float sum is
    /// bit-identical wherever it runs.
    pub fn eval(&self, pl: &[bool]) -> (f64, u64, f64) {
        debug_assert_eq!(pl.len(), self.len());
        let mut e = 0.0;
        let mut d: u64 = 0;
        for (i, &npu) in pl.iter().enumerate() {
            if npu {
                e += self.npu_e[i];
                d = d.max(self.npu_ps[i]);
            } else {
                e += self.pim_e[i];
                d = d.max(self.pim_ps[i]);
            }
        }
        let d = d.max(1);
        (e, d, e * d as f64 * 1e-12)
    }

    fn eval_mask(&self, mask: u64) -> (f64, u64, f64) {
        let pl: Vec<bool> =
            (0..self.len()).map(|i| mask >> i & 1 == 1).collect();
        self.eval(&pl)
    }
}

/// What [`run`] returns: the winning placement with its cost, plus the
/// search-effort counters the `offload` scenario exports as
/// `offload.evals` / `offload.improved`.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub placement: Vec<Placement>,
    pub energy_j: f64,
    /// bottleneck stage time of the chosen placement, ps
    pub delay_ps: u64,
    pub edp: f64,
    /// placements evaluated across the whole search
    pub evals: u64,
    /// accepted strictly-improving moves (hill-climb/bandit), or 1 iff
    /// the winner strictly beats both pure extremes (exhaustive); 0
    /// whenever a pure extreme is optimal
    pub improved: u64,
    /// the strategy that actually ran (`auto` resolved)
    pub strategy: &'static str,
}

fn to_placement(pl: &[bool]) -> Vec<Placement> {
    pl.iter()
        .map(|&npu| if npu { Placement::Npu } else { Placement::Pim })
        .collect()
}

/// Search the placement space of `table` with `strategy`. Deterministic
/// per `(table, strategy, seed)`; thread-count-invariant by the pool's
/// by-index contract plus fixed work decomposition.
pub fn run(table: &LayerTable, strategy: Strategy, seed: u64)
           -> SearchOutcome {
    let resolved = strategy.resolve(table.len());
    let mut out = match resolved {
        Strategy::Exhaustive => exhaustive(table),
        Strategy::HillClimb => hill_climb(table, seed),
        Strategy::Bandit => bandit(table, seed),
        Strategy::Auto => unreachable!("resolve() eliminated Auto"),
    };
    out.strategy = resolved.name();
    out
}

/// One strategy-local best candidate; the reduce key is `(edp, bits)`
/// with `bits` breaking float ties deterministically (lowest mask /
/// lexicographically-smallest placement wins).
struct Best {
    pl: Vec<bool>,
    energy_j: f64,
    delay_ps: u64,
    edp: f64,
}

impl Best {
    fn better_than(&self, other: &Best) -> bool {
        match self.edp.total_cmp(&other.edp) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => self.pl < other.pl,
        }
    }
}

fn exhaustive(table: &LayerTable) -> SearchOutcome {
    let n = table.len();
    assert!(n <= EXHAUSTIVE_MAX,
            "exhaustive search caps at {EXHAUSTIVE_MAX} layers (got {n}); \
             use hillclimb or bandit");
    let total: u64 = 1u64 << n;
    // fixed chunk list (independent of --threads): each item scans its
    // mask range sequentially and returns the local minimum
    let ranges: Vec<(u64, u64)> = (0..total.div_ceil(MASK_CHUNK))
        .map(|c| (c * MASK_CHUNK, ((c + 1) * MASK_CHUNK).min(total)))
        .collect();
    let locals: Vec<Best> = pool::map(&ranges, |&(lo, hi)| {
        let mut best: Option<(u64, f64, u64, f64)> = None;
        for mask in lo..hi {
            let (e, d, edp) = table.eval_mask(mask);
            let better = match &best {
                None => true,
                // lowest mask scans first, so strict-less keeps it
                Some((_, _, _, b)) => edp.total_cmp(b).is_lt(),
            };
            if better {
                best = Some((mask, e, d, edp));
            }
        }
        let (mask, e, d, edp) = best.expect("non-empty mask range");
        Best {
            pl: (0..n).map(|i| mask >> i & 1 == 1).collect(),
            energy_j: e,
            delay_ps: d,
            edp,
        }
    });
    // in-order reduce: ties keep the earlier (lower-mask) chunk
    let mut winner: Option<Best> = None;
    for b in locals {
        if winner.as_ref().map(|w| b.better_than(w)).unwrap_or(true) {
            winner = Some(b);
        }
    }
    let w = winner.expect("at least one chunk");
    // strict win over both pure extremes (masks 0 and 2^n - 1)
    let floor = table
        .eval_mask(0)
        .2
        .min(table.eval_mask(total - 1).2);
    let improved = u64::from(w.edp.total_cmp(&floor).is_lt());
    SearchOutcome {
        placement: to_placement(&w.pl),
        energy_j: w.energy_j,
        delay_ps: w.delay_ps,
        edp: w.edp,
        evals: total,
        improved,
        strategy: Strategy::Exhaustive.name(),
    }
}

/// One climb to a local optimum: index-order single-flip passes,
/// accepting only strict EDP improvements, until a full pass changes
/// nothing. Returns the optimum plus (evals, accepted flips).
fn climb_from(table: &LayerTable, mut pl: Vec<bool>) -> (Best, u64, u64) {
    let n = table.len();
    let (mut e, mut d, mut edp) = table.eval(&pl);
    let mut evals = 1u64;
    let mut improved = 0u64;
    loop {
        let mut any = false;
        for i in 0..n {
            pl[i] = !pl[i];
            let (ne, nd, nedp) = table.eval(&pl);
            evals += 1;
            if nedp.total_cmp(&edp).is_lt() {
                (e, d, edp) = (ne, nd, nedp);
                any = true;
                improved += 1;
            } else {
                pl[i] = !pl[i]; // revert
            }
        }
        if !any {
            break;
        }
    }
    (Best { pl, energy_j: e, delay_ps: d, edp }, evals, improved)
}

fn hill_climb(table: &LayerTable, seed: u64) -> SearchOutcome {
    let n = table.len();
    // starting points built sequentially up front from forked streams,
    // then climbed in parallel: the start list is thread-count-free
    let mut starts: Vec<Vec<bool>> =
        vec![vec![false; n], vec![true; n]];
    let mut root = Pcg::new(seed);
    for r in 0..HILL_RESTARTS {
        let mut rng = root.fork(fork_idx(FORK_NS_OFFLOAD, r));
        starts.push((0..n).map(|_| rng.below(2) == 1).collect());
    }
    let climbs: Vec<(Best, u64, u64)> =
        pool::map(&starts, |s| climb_from(table, s.clone()));
    finish(climbs, Strategy::HillClimb)
}

/// One epsilon-greedy instance: layers are arms, the reward of pulling
/// arm `j` is the EDP drop from flipping layer `j`'s side (a rejected
/// flip reverts, so the current placement only ever improves — and it
/// starts at the better pure extreme, preserving the `<= min(pure)`
/// guarantee).
fn bandit_arm(table: &LayerTable, seed: u64, arm: u64) -> (Best, u64, u64) {
    let n = table.len();
    let mut rng =
        Pcg::new(seed).fork(fork_idx(FORK_NS_OFFLOAD, HILL_RESTARTS + arm));
    let (e_pim, d_pim, edp_pim) = table.eval(&vec![false; n]);
    let (e_npu, d_npu, edp_npu) = table.eval(&vec![true; n]);
    let mut evals = 2u64;
    let mut improved = 0u64;
    // ties keep all-PIM (the lexicographically-smaller placement)
    let mut pl;
    let (mut e, mut d, mut edp);
    if edp_npu.total_cmp(&edp_pim).is_lt() {
        pl = vec![true; n];
        (e, d, edp) = (e_npu, d_npu, edp_npu);
    } else {
        pl = vec![false; n];
        (e, d, edp) = (e_pim, d_pim, edp_pim);
    }
    // optimistic initial estimates: every arm gets pulled at least once
    let mut estimate = vec![f64::INFINITY; n];
    let mut pulls = vec![0u64; n];
    for _ in 0..BANDIT_STEPS_PER_LAYER * n as u64 {
        let j = if rng.uniform() < BANDIT_EPSILON {
            rng.below(n)
        } else {
            // argmax estimate, ties to the lowest index
            let mut best = 0;
            for k in 1..n {
                if estimate[k].total_cmp(&estimate[best]).is_gt() {
                    best = k;
                }
            }
            best
        };
        pl[j] = !pl[j];
        let (ne, nd, nedp) = table.eval(&pl);
        evals += 1;
        let reward = edp - nedp; // positive iff the flip helped
        pulls[j] += 1;
        estimate[j] = if pulls[j] == 1 {
            reward
        } else {
            estimate[j] + (reward - estimate[j]) / pulls[j] as f64
        };
        if nedp.total_cmp(&edp).is_lt() {
            (e, d, edp) = (ne, nd, nedp);
            improved += 1;
        } else {
            pl[j] = !pl[j]; // revert
        }
    }
    (Best { pl, energy_j: e, delay_ps: d, edp }, evals, improved)
}

fn bandit(table: &LayerTable, seed: u64) -> SearchOutcome {
    let arms: Vec<u64> = (0..BANDIT_ARMS).collect();
    let results: Vec<(Best, u64, u64)> =
        pool::map(&arms, |&a| bandit_arm(table, seed, a));
    finish(results, Strategy::Bandit)
}

/// Reduce per-instance results in index order: totals sum, the winner
/// is the `(edp, placement)`-minimal candidate.
fn finish(results: Vec<(Best, u64, u64)>, strategy: Strategy)
          -> SearchOutcome {
    let evals: u64 = results.iter().map(|(_, ev, _)| ev).sum();
    let improved: u64 = results.iter().map(|(_, _, im)| im).sum();
    let mut winner: Option<Best> = None;
    for (b, _, _) in results {
        if winner.as_ref().map(|w| b.better_than(w)).unwrap_or(true) {
            winner = Some(b);
        }
    }
    let w = winner.expect("at least one search instance");
    SearchOutcome {
        placement: to_placement(&w.pl),
        energy_j: w.energy_j,
        delay_ps: w.delay_ps,
        edp: w.edp,
        evals,
        improved,
        strategy: strategy.name(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built table: layer 1 is cheaper on the NPU, the rest on
    /// PIM; stage times are equal so EDP ordering follows energy.
    fn toy() -> LayerTable {
        LayerTable {
            pim_e: vec![1.0, 5.0, 2.0],
            npu_e: vec![2.0, 1.0, 3.0],
            pim_ps: vec![100, 100, 100],
            npu_ps: vec![100, 100, 100],
        }
    }

    #[test]
    fn eval_takes_each_side_from_its_vector() {
        let t = toy();
        let (e, d, edp) = t.eval(&[false, true, false]);
        assert_eq!(e, 1.0 + 1.0 + 2.0);
        assert_eq!(d, 100);
        assert!((edp - e * 100e-12).abs() < 1e-18);
        // delay is the max over the *chosen* sides
        let mut t2 = toy();
        t2.npu_ps[1] = 900;
        assert_eq!(t2.eval(&[false, true, false]).1, 900);
        assert_eq!(t2.eval(&[false, false, false]).1, 100);
    }

    #[test]
    fn exhaustive_finds_the_per_layer_optimum() {
        let t = toy();
        let out = run(&t, Strategy::Exhaustive, 42);
        assert_eq!(out.strategy, "exhaustive");
        assert_eq!(out.evals, 8);
        assert_eq!(
            out.placement,
            vec![Placement::Pim, Placement::Npu, Placement::Pim]
        );
        assert_eq!(out.energy_j, 4.0);
    }

    #[test]
    fn every_strategy_beats_or_matches_both_extremes() {
        let t = toy();
        let (_, _, edp_pim) = t.eval(&[false; 3]);
        let (_, _, edp_npu) = t.eval(&[true; 3]);
        let floor = edp_pim.min(edp_npu);
        for s in [Strategy::Exhaustive, Strategy::HillClimb,
                  Strategy::Bandit] {
            let out = run(&t, s, 42);
            assert!(out.edp <= floor, "{:?}: {} > {floor}", s, out.edp);
            assert!(out.evals >= 2);
        }
    }

    #[test]
    fn auto_resolves_by_network_size() {
        let small = toy();
        assert_eq!(run(&small, Strategy::Auto, 1).strategy, "exhaustive");
        let n = EXHAUSTIVE_MAX + 1;
        let big = LayerTable {
            pim_e: vec![1.0; n],
            npu_e: vec![2.0; n],
            pim_ps: vec![10; n],
            npu_ps: vec![10; n],
        };
        assert_eq!(run(&big, Strategy::Auto, 1).strategy, "hillclimb");
    }

    #[test]
    fn seeded_strategies_are_reproducible() {
        let t = toy();
        for s in [Strategy::HillClimb, Strategy::Bandit] {
            let a = run(&t, s, 7);
            let b = run(&t, s, 7);
            assert_eq!(a.placement, b.placement, "{s:?}");
            assert_eq!(a.edp.to_bits(), b.edp.to_bits(), "{s:?}");
            assert_eq!(a.evals, b.evals, "{s:?}");
        }
    }

    #[test]
    fn parse_rejects_unknown_strategies() {
        assert!(Strategy::parse("auto").is_ok());
        assert!(Strategy::parse("exhaustive").is_ok());
        let err = Strategy::parse("anneal").unwrap_err();
        assert!(err.to_string().contains("auto, exhaustive"), "{err}");
    }
}
