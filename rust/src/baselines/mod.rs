//! PE-level comparison of the registered architectures (Table 3) and the
//! per-architecture configuration summaries the report module renders.
//! Entirely registry-driven: a newly registered cost model appears here
//! (and in `report::table3`) with no edits.

use crate::config::Architecture;
use crate::energy;
use crate::model;

#[derive(Debug, Clone)]
pub struct PeComparison {
    pub arch: Architecture,
    pub accumulation: &'static str,
    pub interface: &'static str,
    pub dac_bits: u32,
    pub adc_bits: u32,
    pub adcs_per_64_arrays: u32,
    pub density_pct: f64,
    pub cells_per_mm2: f64,
    pub pe_power_w: f64,
    pub pe_area_mm2: f64,
}

pub fn pe_comparison() -> Vec<PeComparison> {
    model::models()
        .iter()
        .map(|m| {
            let cfg = m.default_config();
            let pe = energy::pe_budget(&cfg);
            let meta = m.pe_metadata(&cfg);
            PeComparison {
                arch: m.arch(),
                accumulation: meta.accumulation,
                interface: meta.interface,
                dac_bits: cfg.precision.p_d,
                adc_bits: meta.adc_bits,
                adcs_per_64_arrays: cfg.adcs_per_pe * 64 / cfg.arrays_per_pe,
                density_pct: pe.compute_density() * 100.0,
                cells_per_mm2: pe.cells_per_mm2(&cfg),
                pe_power_w: pe.power(),
                pe_area_mm2: pe.area(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_row_shapes() {
        let rows = pe_comparison();
        assert_eq!(rows.len(), model::archs().len());
        let get = |arch: Architecture| {
            rows.iter().find(|r| r.arch == arch).unwrap()
        };
        let isaac = get(Architecture::IsaacLike);
        let cascade = get(Architecture::CascadeLike);
        let np = get(Architecture::NeuralPim);
        let lowres = get(Architecture::LowResolution);
        // Table 3's headline facts
        assert_eq!(isaac.adcs_per_64_arrays, 64);
        assert_eq!(cascade.adcs_per_64_arrays, 3);
        assert_eq!(np.adcs_per_64_arrays, 4);
        assert_eq!(isaac.dac_bits, 1);
        assert_eq!(np.dac_bits, 4);
        assert_eq!(isaac.adc_bits, 7);
        assert_eq!(cascade.adc_bits, 10);
        assert_eq!(np.adc_bits, 8);
        // the RAELLA-style reform's whole point: fewer converter bits
        // than the ISAAC-style baseline, on the same organization
        assert!(lowres.adc_bits < isaac.adc_bits);
        assert_eq!(lowres.adcs_per_64_arrays, 64);
        assert!(lowres.pe_area_mm2 < isaac.pe_area_mm2);
    }

    #[test]
    fn density_within_table3_band() {
        // Table 3: 4.5e6 / 5.0e6 / 4.6e6 cells/mm² — we accept 3x bands
        // (our area model is component-level, not layout-level)
        for row in pe_comparison() {
            assert!(row.cells_per_mm2 > 1e6 && row.cells_per_mm2 < 2e8,
                    "{:?}: {}", row.arch, row.cells_per_mm2);
        }
    }
}
