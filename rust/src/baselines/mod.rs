//! PE-level comparison of the three architectures (Table 3) and the
//! per-architecture configuration summaries the report module renders.

use crate::config::{AcceleratorConfig, Architecture};
use crate::dataflow;
use crate::energy;

#[derive(Debug, Clone)]
pub struct PeComparison {
    pub arch: Architecture,
    pub accumulation: &'static str,
    pub interface: &'static str,
    pub dac_bits: u32,
    pub adc_bits: u32,
    pub adcs_per_64_arrays: u32,
    pub density_pct: f64,
    pub cells_per_mm2: f64,
    pub pe_power_w: f64,
    pub pe_area_mm2: f64,
}

pub fn pe_comparison() -> Vec<PeComparison> {
    Architecture::all()
        .iter()
        .map(|&arch| {
            let cfg = AcceleratorConfig::for_arch(arch);
            let pe = energy::pe_budget(&cfg);
            let p = &cfg.precision;
            let n = cfg.n_log2();
            let (accumulation, interface, adc_bits) = match arch {
                Architecture::IsaacLike => (
                    "Digital",
                    "S+A",
                    // the paper's Table 3 lists 7-bit for the ISAAC-style
                    // baseline (one fewer than Eq. 2's worst case, since
                    // one BL level is spare); we report Eq. 2 - 1
                    dataflow::adc_resolution_a(p, n) - 1,
                ),
                Architecture::CascadeLike => (
                    "Partially analog",
                    "S+A and buffer array",
                    dataflow::adc_resolution_b(p, n) - 1,
                ),
                Architecture::NeuralPim => (
                    "Analog",
                    "NNS+A",
                    dataflow::adc_resolution_c(p),
                ),
            };
            PeComparison {
                arch,
                accumulation,
                interface,
                dac_bits: p.p_d,
                adc_bits,
                adcs_per_64_arrays: cfg.adcs_per_pe * 64 / cfg.arrays_per_pe,
                density_pct: pe.compute_density() * 100.0,
                cells_per_mm2: pe.cells_per_mm2(&cfg),
                pe_power_w: pe.power(),
                pe_area_mm2: pe.area(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_row_shapes() {
        let rows = pe_comparison();
        assert_eq!(rows.len(), 3);
        let isaac = &rows[0];
        let cascade = &rows[1];
        let np = &rows[2];
        // Table 3's headline facts
        assert_eq!(isaac.adcs_per_64_arrays, 64);
        assert_eq!(cascade.adcs_per_64_arrays, 3);
        assert_eq!(np.adcs_per_64_arrays, 4);
        assert_eq!(isaac.dac_bits, 1);
        assert_eq!(np.dac_bits, 4);
        assert_eq!(isaac.adc_bits, 7);
        assert_eq!(cascade.adc_bits, 10);
        assert_eq!(np.adc_bits, 8);
    }

    #[test]
    fn density_within_table3_band() {
        // Table 3: 4.5e6 / 5.0e6 / 4.6e6 cells/mm² — we accept 3x bands
        // (our area model is component-level, not layout-level)
        for row in pe_comparison() {
            assert!(row.cells_per_mm2 > 1e6 && row.cells_per_mm2 < 2e8,
                    "{:?}: {}", row.arch, row.cells_per_mm2);
        }
    }
}
