//! Ablation studies over the design choices DESIGN.md calls out:
//! buffer-cell precision (Strategy B's Achilles heel), the NNADC
//! range-bank count (§4.2), and the charge-transfer ordering (LSB- vs
//! MSB-first). All native behavioural models; `neural-pim characterize`
//! and the noise bench consume these.

use crate::arch::crossbar::Group;
use crate::noise;
use crate::util::rng::Pcg;
use crate::util::stats;

/// Strategy-B SINAD as a function of buffer-cell precision: the §3.3
/// argument ("fundamentally limited by buffer RRAM's precision") made
/// quantitative. Returns (bits, sinad_db) pairs.
pub fn buffer_precision_sweep(bits_list: &[u32], n: usize, seed: u64)
                              -> Vec<(u32, f64)> {
    bits_list
        .iter()
        .map(|&bits| {
            let mut rng = Pcg::new(seed);
            let (group, xs) = noise::correlated_batch(&mut rng, n, 128);
            let mut hw = Vec::with_capacity(n);
            let mut sw = Vec::with_capacity(n);
            for x in &xs {
                sw.push(group.dot(x) as f64);
                hw.push(strategy_b_at_precision(&group, x, bits, &mut rng));
            }
            (bits, stats::sinad_db(&hw, &sw))
        })
        .collect()
}

fn strategy_b_at_precision(group: &Group, x: &[u32], buffer_bits: u32,
                           rng: &mut Pcg) -> f64 {
    let pd = 1u32;
    let partial = group.partial_sums(x, pd);
    let fs = 128.0;
    let buf_levels = (1u64 << buffer_bits) as f64 - 1.0;
    let adc_levels = 1023.0;
    let sigma = 0.025;
    let n_exp = (partial.len() - 1) + 8;
    let mut diag = vec![(0.0f64, 0.0f64, 0u32); n_exp + 1];
    for (s, planes) in partial.iter().enumerate() {
        for (j, &v) in planes.iter().enumerate() {
            let (pp, pn) = if v >= 0 { (v as f64, 0.0) } else { (0.0, -v as f64) };
            let e = s + j;
            diag[e].0 += crate::arch::quantize_uniform(pp, buf_levels, fs)
                * rng.lognormal_factor(sigma);
            diag[e].1 += crate::arch::quantize_uniform(pn, buf_levels, fs)
                * rng.lognormal_factor(sigma);
            diag[e].2 += 1;
        }
    }
    let mut total = 0.0;
    for (e, &(p, nn, c)) in diag.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let fs_bl = fs * c as f64;
        total += 2f64.powi(e as i32)
            * (crate::arch::quantize_uniform(p, adc_levels, fs_bl)
                - crate::arch::quantize_uniform(nn, adc_levels, fs_bl));
    }
    total.round()
}

/// Strategy-C SINAD vs the number of range-aware NNADC banks (§4.2's
/// "three pre-trained NNADCs" choice): 0 banks = full-rail conversion,
/// k banks = V_max in {VDD, VDD/2, ..., VDD/2^k}. Returns (banks, sinad).
pub fn range_bank_sweep(banks_list: &[u32], n: usize, seed: u64)
                        -> Vec<(u32, f64)> {
    banks_list
        .iter()
        .map(|&banks| {
            let mut rng = Pcg::new(seed);
            let (group, xs) = noise::correlated_batch(&mut rng, n, 128);
            // observed swing drives the bank selection
            let d_abs_max = xs
                .iter()
                .map(|x| group.dot(x).unsigned_abs())
                .max()
                .unwrap_or(1) as f64;
            let worst = 128.0 * 255.0 * 127.0;
            // smallest available bank that still covers the swing
            let mut fs = worst;
            for k in 1..=banks {
                let cand = worst / 2f64.powi(k as i32);
                if d_abs_max <= cand {
                    fs = cand;
                }
            }
            let mut hw = Vec::with_capacity(n);
            let mut sw = Vec::with_capacity(n);
            for x in &xs {
                sw.push(group.dot(x) as f64);
                hw.push(group.strategy_c(x, 4, 255.0, fs));
            }
            (banks, stats::sinad_db(&hw, &sw))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_precision_improves_sinad_monotonically() {
        let rows = buffer_precision_sweep(&[3, 6, 10], 300, 5);
        assert!(rows[1].1 > rows[0].1 + 3.0,
                "6-bit {} vs 3-bit {}", rows[1].1, rows[0].1);
        assert!(rows[2].1 > rows[1].1,
                "10-bit {} vs 6-bit {}", rows[2].1, rows[1].1);
    }

    #[test]
    fn six_bit_buffer_is_the_paper_operating_point() {
        // CASCADE's 6-bit cells: usable but the lowest marker of Fig. 10
        let rows = buffer_precision_sweep(&[6], 300, 7);
        assert!(rows[0].1 > 10.0 && rows[0].1 < 45.0, "{}", rows[0].1);
    }

    #[test]
    fn range_banks_buy_sinad() {
        // each halving of V_max is worth ~6 dB until the swing is covered
        let rows = range_bank_sweep(&[0, 2, 4], 300, 9);
        assert!(rows[1].1 > rows[0].1 + 5.0,
                "2 banks {} vs 0 {}", rows[1].1, rows[0].1);
        assert!(rows[2].1 >= rows[1].1 - 1e-9);
    }
}
