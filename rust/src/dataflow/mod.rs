//! §3's unified characterization framework: Eqs. (2)–(8) plus the
//! array-level energy/latency model behind Fig. 4(b)/(c).
//!
//! Everything operates on a single dot-product *group* (one signed weight
//! vector down a 2^N-row crossbar) and scales linearly to full arrays —
//! exactly the paper's "derived based on a single group of inputs and
//! weights" framing.
//!
//! These are the *equations*; which equation a given architecture uses
//! is bound by its `model::CostModel` impl (`model/archs.rs`) — nothing
//! else in the crate picks an equation by matching on an architecture.
//! The [`Strategy`] enum below stays closed on purpose: it is the
//! paper's §3 taxonomy of the three accumulation strategies behind
//! Fig. 3/4, not the open set of registered architectures.

use crate::config::Precision;
use crate::energy::constants as k;

/// Eq. (2): A/D resolution Strategy A needs to capture a raw BL sum.
pub fn adc_resolution_a(p: &Precision, n: u32) -> u32 {
    if p.p_r > 1 && p.p_d > 1 {
        p.p_r + p.p_d + n
    } else {
        p.p_r + p.p_d - 1 + n
    }
}

/// Eq. (3): Strategy B's buffer-BL resolution — Strategy A's plus
/// ceil(log2(input cycles)) for the buffer-row accumulation. Integer
/// ceil-log2 ([`crate::util::num::ceil_log2`]): the float route can
/// round across power-of-two boundaries and mis-size the ADC.
pub fn adc_resolution_b(p: &Precision, n: u32) -> u32 {
    adc_resolution_a(p, n) + crate::util::num::ceil_log2(p.input_cycles() as u64)
}

/// Eq. (4): Strategy C only extracts the P_O MSBs of the final analog sum.
pub fn adc_resolution_c(p: &Precision) -> u32 {
    p.p_o
}

/// Eq. (5): A/D conversions per dot-product group, Strategy A.
pub fn conversions_a(p: &Precision) -> u64 {
    p.input_cycles() as u64 * p.weight_cols() as u64
}

/// Eq. (6): conversions per group, Strategy B (radix-aligned buffer BLs).
pub fn conversions_b(p: &Precision) -> u64 {
    p.input_cycles() as u64 + p.weight_cols() as u64 - 1
}

/// Eq. (7): one conversion per group, Strategy C.
pub fn conversions_c() -> u64 {
    1
}

/// Eq. (8): computation latency in input cycles — identical across
/// strategies (bit-sliced streaming).
pub fn latency_cycles(p: &Precision) -> u64 {
    p.input_cycles() as u64
}

/// Buffer-cell precision Strategy B must write (footnote 1: one RRAM cell
/// buffers one high-precision analog partial sum at Strategy A's BL
/// resolution).
pub fn buffer_cell_bits(p: &Precision, n: u32) -> u32 {
    adc_resolution_a(p, n)
}

/// State-of-the-art fabricated multi-level RRAM precision. §3.3: Strategy
/// B "can only adopt low-resolution DACs" because the buffer cell needs
/// > 7 bits once P_D >= 2; at P_D = 1 (Eq. 2 gives 8 bits) CASCADE still
/// builds it (footnote 1), so the feasibility threshold is 8.
pub const MAX_FABRICABLE_CELL_BITS: u32 = 8;

/// Is Strategy B physically buildable at this configuration? (§3.3: with
/// P_D >= 2 the buffer cell would need > 7 bits.)
pub fn strategy_b_feasible(p: &Precision, n: u32) -> bool {
    buffer_cell_bits(p, n) <= MAX_FABRICABLE_CELL_BITS
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    A,
    B,
    C,
}

impl Strategy {
    pub fn all() -> [Strategy; 3] {
        [Strategy::A, Strategy::B, Strategy::C]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::A => "A (digital acc.)",
            Strategy::B => "B (buffered analog)",
            Strategy::C => "C (fully analog)",
        }
    }
}

/// Array-level per-group energy breakdown for one full-precision VMM —
/// the quantities behind Fig. 4(b) (normalized efficiency vs DAC bits)
/// and Fig. 4(c) (component breakdown).
#[derive(Debug, Clone, Default)]
pub struct GroupEnergy {
    pub adc: f64,
    pub dac: f64,
    pub sa: f64, // S+A: digital units, buffer writes, or NNS+A ops
    pub xbar: f64,
    pub other: f64,
}

impl GroupEnergy {
    pub fn total(&self) -> f64 {
        self.adc + self.dac + self.sa + self.xbar + self.other
    }
}

/// Energy for one dot-product group of one full-precision input vector
/// down a 2^N-row crossbar, per strategy.
///
/// Conventions: the group owns `rows = 2^N` wordlines and
/// `2 * weight_cols` bitlines (W+/W- pairs). DAC/crossbar energy is
/// charged per group as the array's per-cycle energy divided by the
/// groups sharing it.
pub fn group_energy(s: Strategy, p: &Precision, n: u32) -> GroupEnergy {
    let rows = 1u64 << n;
    let cycles = p.input_cycles() as u64;
    let groups_per_array = (1u64 << n) / (2 * p.weight_cols() as u64);

    // wordline side: every cycle drives all rows (shared by all groups)
    let dac = cycles as f64 * rows as f64 * k::dac_e_cycle(p.p_d)
        / groups_per_array as f64;
    let xbar = cycles as f64 * k::xbar_e_cycle(1 << n, p.p_d)
        / groups_per_array as f64;
    let mut e = GroupEnergy { dac, xbar, ..Default::default() };

    match s {
        Strategy::A => {
            let bits = adc_resolution_a(p, n);
            // each of the 2*weight_cols BLs converts every cycle (Eq. 5,
            // doubled for the W+/W- pair)
            let convs = 2 * conversions_a(p);
            e.adc = convs as f64 * k::adc_e_conv(bits);
            // one digital S+A op per conversion + OR read/write traffic
            e.sa = convs as f64 * k::SA_DIGITAL_E_OP;
            e.other = convs as f64 * 2.0 * k::SRAM_E_BYTE; // OR in/out (step 3/5)
        }
        Strategy::B => {
            // the TIA subtracts the W+/W- pair in the analog domain, so
            // one (single-ended) buffer cell per (cycle, bit-column)
            let writes = cycles * p.weight_cols() as u64;
            e.sa = writes as f64 * k::BUFFER_WRITE_E
                + cycles as f64 * k::TIA_E_CYCLE
                + conversions_b(p) as f64 * k::SA_DIGITAL_E_OP;
            // 8-bit-energy-class converters at 10-bit nominal resolution
            // (constants::CASCADE_ADC_E_CONV)
            e.adc = conversions_b(p) as f64 * k::CASCADE_ADC_E_CONV;
            e.other = conversions_b(p) as f64 * k::SUMAMP_E_CYCLE;
        }
        Strategy::C => {
            let bits = adc_resolution_c(p);
            // one NNS+A accumulation op per input cycle (covers all 8 BL
            // pairs of the group) + S/H holds + ONE conversion
            e.sa = cycles as f64 * k::NNSA_E_OP
                + cycles as f64 * 2.0 * k::SH_E_OP;
            e.adc = conversions_c() as f64 * k::NNADC_E_CONV
                * 2f64.powi(bits as i32 - 8); // range-aware stays 8-bit
        }
    }
    e
}

/// Fig. 4(b): energy of a full VMM normalized to Strategy A at 1-bit DACs.
pub fn fig4b_normalized_energy(p_d_values: &[u32], n: u32) -> Vec<(u32, f64, f64, Option<f64>)> {
    let base_p = Precision { p_d: 1, ..Default::default() };
    let base = group_energy(Strategy::A, &base_p, n).total();
    p_d_values
        .iter()
        .map(|&pd| {
            let p = Precision { p_d: pd, ..Default::default() };
            let ea = group_energy(Strategy::A, &p, n).total() / base;
            let ec = group_energy(Strategy::C, &p, n).total() / base;
            let eb = if strategy_b_feasible(&p, n) {
                Some(group_energy(Strategy::B, &p, n).total() / base)
            } else {
                None // §3.3: buffer cell would exceed fabricable precision
            };
            (pd, ea, ec, eb)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(p_d: u32, p_r: u32) -> Precision {
        Precision { p_d, p_r, ..Default::default() }
    }

    #[test]
    fn eq2_examples() {
        // N=7, PR=1, PD=1 -> 1+1-1+7 = 8
        assert_eq!(adc_resolution_a(&p(1, 1), 7), 8);
        // PR=2, PD=2 -> 2+2+7 = 11
        assert_eq!(adc_resolution_a(&p(2, 2), 7), 11);
        // PD=4, PR=1 -> 1+4-1+7 = 11
        assert_eq!(adc_resolution_a(&p(4, 1), 7), 11);
    }

    #[test]
    fn eq3_adds_log_cycles() {
        // PD=1: 8 cycles -> +3 bits
        assert_eq!(adc_resolution_b(&p(1, 1), 7), 11);
        // PD=2: Eq.2 gives 9 bits, 4 cycles -> +2 bits
        assert_eq!(adc_resolution_b(&p(2, 1), 7), 11);
    }

    #[test]
    fn prop_eq3_matches_float_ceil_log2_over_precision_sweep() {
        // the exact integer ceil-log2 must agree with the float version
        // everywhere the §3/§7.1 sweeps can reach: every (P_I, P_D)
        // pair with 1 <= P_D <= P_I <= 64 (input_cycles = ceil(P_I/P_D))
        // and every N in the fabricable crossbar range
        crate::util::prop::check("eq3 integer vs float", 400, |g| {
            let p_i = g.usize_in(1, 64) as u32;
            let p_d = g.usize_in(1, p_i as usize) as u32;
            let p_r = g.usize_in(1, 6) as u32;
            let n = g.usize_in(5, 9) as u32;
            let p = Precision { p_i, p_d, p_r, ..Default::default() };
            let float_bits = adc_resolution_a(&p, n)
                + (p.input_cycles() as f64).log2().ceil() as u32;
            let got = adc_resolution_b(&p, n);
            if got != float_bits {
                return Err(format!(
                    "P_I={p_i} P_D={p_d} (cycles {}): exact {got} vs \
                     float {float_bits}",
                    p.input_cycles()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn eq5_to_eq7_conversion_counts() {
        // the paper's §3.1 example: 8-bit weights, 1-bit cells, 1-bit DACs
        let pr = p(1, 1);
        assert_eq!(conversions_a(&pr), 64); // 8 x 8
        assert_eq!(conversions_b(&pr), 15); // 8 + 8 - 1
        assert_eq!(conversions_c(), 1);
    }

    #[test]
    fn eq8_latency() {
        assert_eq!(latency_cycles(&p(1, 1)), 8);
        assert_eq!(latency_cycles(&p(4, 1)), 2);
        assert_eq!(latency_cycles(&p(8, 1)), 1);
    }

    #[test]
    fn strategy_b_infeasible_beyond_1bit_dacs() {
        // §3.3: buffer cell needs > 7 bits when P_D >= 2 at N = 7, so only
        // the 1-bit-DAC point of Fig. 4(b) reports a Strategy-B bar
        assert!(strategy_b_feasible(&p(1, 1), 7));
        assert!(!strategy_b_feasible(&p(2, 1), 7));
        assert!(!strategy_b_feasible(&p(4, 1), 7));
    }

    #[test]
    fn strategy_c_minimizes_adc_energy() {
        for pd in [1, 2, 4] {
            let pr = p(pd, 1);
            let ea = group_energy(Strategy::A, &pr, 7);
            let ec = group_energy(Strategy::C, &pr, 7);
            assert!(ec.adc < ea.adc / 10.0,
                    "pd={pd}: C adc {} vs A adc {}", ec.adc, ea.adc);
            assert!(ec.total() < ea.total());
        }
    }

    #[test]
    fn fig4b_trends() {
        // Strategy A degrades with DAC resolution; Strategy C improves
        let rows = fig4b_normalized_energy(&[1, 2, 4], 7);
        let ea: Vec<f64> = rows.iter().map(|r| r.1).collect();
        let ec: Vec<f64> = rows.iter().map(|r| r.2).collect();
        assert!(ea[2] > ea[0], "A should worsen: {:?}", ea);
        assert!(ec[2] < ec[0], "C should improve: {:?}", ec);
        // B only reported at 1-bit DACs (Fig. 4 note)
        assert!(rows[0].3.is_some());
        assert!(rows[1].3.is_none() && rows[2].3.is_none());
    }

    #[test]
    fn isaac_energy_is_adc_dominated_fig4c() {
        let e = group_energy(Strategy::A, &p(1, 1), 7);
        assert!(e.adc / e.total() > 0.45, "adc share {}", e.adc / e.total());
    }
}

pub mod ablation;
