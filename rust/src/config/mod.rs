//! Typed accelerator configuration.
//!
//! One [`AcceleratorConfig`] instance fully describes a chip: the §7.1
//! design-space hyper-parameters (N, M, A, S, D), the precision settings
//! of §3.2, and the physical organization (PEs/tile, tiles/chip). The DSE
//! engine (`dse/`) sweeps these; the simulator (`sim/`) consumes them.
//!
//! Configs load from JSON (`--config file.json`) or from CLI overrides,
//! and always pass [`AcceleratorConfig::validate`] before use.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Which accumulation architecture the chip implements (Fig. 3).
///
/// This enum is only an *id*: everything an architecture IS — its
/// dataflow equations, default chip, per-layer energy, PE periphery,
/// Table-3 metadata — lives behind the [`crate::model::CostModel`]
/// registered for the variant in `model/archs.rs`. Adding a variant
/// here plus an impl there registers a new architecture everywhere
/// (`simulate --all`, `table3`, iso-area comparisons, `event-sim`, DSE)
/// with no further call-site edits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Architecture {
    /// Strategy A: per-conversion digital accumulation (ISAAC-style).
    IsaacLike,
    /// Strategy B: RRAM buffer arrays + shared ADCs (CASCADE-style).
    CascadeLike,
    /// Strategy C: fully-analog accumulation with NeuralPeriph circuits.
    NeuralPim,
    /// RAELLA-style speculative low-resolution conversion
    /// (`model::archs::LowResolutionModel`).
    LowResolution,
    /// All-digital NPU: SRAM-held weights, MAC lanes, no converters
    /// (`model::archs::NpuModel`) — the offload target of `offload/`.
    DigitalNpu,
}

impl Architecture {
    /// Display name, from the registered cost model.
    pub fn name(&self) -> &'static str {
        crate::model::cost_model(*self).name()
    }

    /// Parse a CLI spelling against every registered model's aliases.
    pub fn parse(s: &str) -> Result<Architecture> {
        crate::model::parse_arch(s)
    }
}

/// Precision configuration (§3.2 symbols).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Precision {
    pub p_i: u32, // input bits
    pub p_w: u32, // weight bits
    pub p_o: u32, // output bits
    pub p_r: u32, // RRAM cell bits in VMM arrays
    pub p_d: u32, // DAC resolution
}

impl Default for Precision {
    fn default() -> Self {
        Precision { p_i: 8, p_w: 8, p_o: 8, p_r: 1, p_d: 1 }
    }
}

impl Precision {
    /// Input cycles per full-precision input: ceil(P_I / P_D) (Eq. 8).
    pub fn input_cycles(&self) -> u32 {
        self.p_i.div_ceil(self.p_d)
    }

    /// RRAM columns per unsigned weight: ceil(P_W / P_R).
    pub fn weight_cols(&self) -> u32 {
        self.p_w.div_ceil(self.p_r)
    }
}

/// Full accelerator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorConfig {
    pub arch: Architecture,
    pub precision: Precision,
    /// crossbar side (rows == cols == `xbar_size`); §7.1's N is log2 of this
    pub xbar_size: u32,
    /// crossbar arrays per PE (§7.1's M)
    pub arrays_per_pe: u32,
    /// ADCs (or NNADCs) shared by one PE (§7.1's A)
    pub adcs_per_pe: u32,
    /// NNS+A circuits per crossbar array (§7.1's S); ignored by baselines
    pub sa_per_array: u32,
    pub pes_per_tile: u32,
    pub tiles: u32,
    /// input cycle time, ns (paper: 100 ns, §5.2.4)
    pub cycle_ns: f64,
    /// eDRAM buffer per tile, bytes
    pub edram_bytes: u64,
    /// c-mesh concentration (tiles per router)
    pub noc_concentration: u32,
}

impl AcceleratorConfig {
    /// The paper's optimal Neural-PIM configuration (§7.1, Table 2):
    /// 64 128x128 arrays/PE, 4 NNADCs, 64 NNS+As, 4-bit DACs, 280 tiles.
    pub fn neural_pim() -> Self {
        Self::for_arch(Architecture::NeuralPim)
    }

    /// ISAAC-style baseline scaled to 8-bit inference (§6.1, Table 3):
    /// one 8-bit ADC per array, 1-bit DACs, digital S+A.
    pub fn isaac_like() -> Self {
        Self::for_arch(Architecture::IsaacLike)
    }

    /// CASCADE-style baseline (§6.1, Table 3): buffer arrays, TIAs,
    /// 3 shared 10-bit ADCs per 64 arrays, 1-bit DACs.
    pub fn cascade_like() -> Self {
        Self::for_arch(Architecture::CascadeLike)
    }

    /// The architecture's registered default chip
    /// ([`crate::model::CostModel::default_config`]).
    pub fn for_arch(arch: Architecture) -> Self {
        crate::model::cost_model(arch).default_config()
    }

    /// §3.2's N (log2 of crossbar side).
    pub fn n_log2(&self) -> u32 {
        self.xbar_size.trailing_zeros()
    }

    /// 8-bit signed weights per crossbar array (W+/W- pairs, §5.2.1).
    pub fn weights_per_array(&self) -> u64 {
        let cols_per_weight = 2 * self.precision.weight_cols() as u64;
        (self.xbar_size as u64 / cols_per_weight) * self.xbar_size as u64
    }

    /// dot-product groups per array (columns / columns-per-weight).
    pub fn groups_per_array(&self) -> u64 {
        self.xbar_size as u64 / (2 * self.precision.weight_cols() as u64)
    }

    pub fn total_arrays(&self) -> u64 {
        self.tiles as u64 * self.pes_per_tile as u64 * self.arrays_per_pe as u64
    }

    /// Peak MAC ops per second: every array row x group, both multiply and
    /// add counted (the paper's GOPS convention), per full-input period.
    pub fn peak_gops(&self) -> f64 {
        let macs_per_array =
            self.xbar_size as f64 * self.groups_per_array() as f64;
        let input_period_s =
            self.precision.input_cycles() as f64 * self.cycle_ns * 1e-9;
        2.0 * macs_per_array * self.total_arrays() as f64 / input_period_s / 1e9
    }

    pub fn validate(&self) -> Result<()> {
        if !self.xbar_size.is_power_of_two() {
            bail!("xbar_size must be a power of two (got {})", self.xbar_size);
        }
        if self.xbar_size < 32 || self.xbar_size > 512 {
            bail!("xbar_size out of the fabricable range [32, 512] (§2.2)");
        }
        if self.precision.p_d == 0 || self.precision.p_d > self.precision.p_i {
            bail!("DAC resolution must be in [1, P_I]");
        }
        if self.precision.p_r == 0 || self.precision.p_r > 6 {
            bail!("RRAM cell precision must be in [1, 6] bits (§2.2)");
        }
        if self.xbar_size < 2 * self.precision.weight_cols() {
            bail!("array narrower than one signed weight");
        }
        if self.arrays_per_pe == 0 || self.pes_per_tile == 0 || self.tiles == 0 {
            bail!("counts must be positive");
        }
        if self.adcs_per_pe == 0 {
            bail!("need at least one ADC per PE");
        }
        // architecture-specific rules live with the cost model
        crate::model::cost_model(self.arch).validate_config(self)?;
        Ok(())
    }

    // ------------------------------------------------------------- JSON --

    pub fn from_json(j: &Json) -> Result<Self> {
        let arch = Architecture::parse(
            j.get("arch").and_then(Json::as_str).unwrap_or("neural-pim"))?;
        let mut c = AcceleratorConfig::for_arch(arch);
        let num = |key: &str, tgt: &mut u32| {
            if let Some(v) = j.get(key).and_then(Json::as_f64) {
                *tgt = v as u32;
            }
        };
        num("xbar_size", &mut c.xbar_size);
        num("arrays_per_pe", &mut c.arrays_per_pe);
        num("adcs_per_pe", &mut c.adcs_per_pe);
        num("sa_per_array", &mut c.sa_per_array);
        num("pes_per_tile", &mut c.pes_per_tile);
        num("tiles", &mut c.tiles);
        num("dac_bits", &mut c.precision.p_d);
        num("rram_bits", &mut c.precision.p_r);
        if let Some(v) = j.get("cycle_ns").and_then(Json::as_f64) {
            c.cycle_ns = v;
        }
        c.validate().context("invalid accelerator config")?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn to_json(&self) -> Json {
        crate::util::json::obj(vec![
            ("arch", Json::Str(self.arch.name().into())),
            ("xbar_size", Json::Num(self.xbar_size as f64)),
            ("arrays_per_pe", Json::Num(self.arrays_per_pe as f64)),
            ("adcs_per_pe", Json::Num(self.adcs_per_pe as f64)),
            ("sa_per_array", Json::Num(self.sa_per_array as f64)),
            ("pes_per_tile", Json::Num(self.pes_per_tile as f64)),
            ("tiles", Json::Num(self.tiles as f64)),
            ("dac_bits", Json::Num(self.precision.p_d as f64)),
            ("rram_bits", Json::Num(self.precision.p_r as f64)),
            ("cycle_ns", Json::Num(self.cycle_ns)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        for arch in crate::model::archs() {
            AcceleratorConfig::for_arch(arch).validate().unwrap();
        }
    }

    #[test]
    fn parse_accepts_registered_aliases() {
        assert_eq!(Architecture::parse("isaac").unwrap(),
                   Architecture::IsaacLike);
        assert_eq!(Architecture::parse("B").unwrap(),
                   Architecture::CascadeLike);
        assert_eq!(Architecture::parse("NeuralPIM").unwrap(),
                   Architecture::NeuralPim);
        assert_eq!(Architecture::parse("raella").unwrap(),
                   Architecture::LowResolution);
        assert!(Architecture::parse("tpu").is_err());
    }

    #[test]
    fn paper_table2_shape() {
        let c = AcceleratorConfig::neural_pim();
        assert_eq!(c.n_log2(), 7);
        assert_eq!(c.precision.input_cycles(), 2); // 4-bit DAC, 8-bit input
        assert_eq!(c.groups_per_array(), 8); // 128 / (2*8)
        assert_eq!(c.weights_per_array(), 1024); // §5.2.1
        assert_eq!(c.total_arrays(), 280 * 4 * 64);
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = AcceleratorConfig::neural_pim();
        c.xbar_size = 100;
        assert!(c.validate().is_err());
        let mut c = AcceleratorConfig::neural_pim();
        c.precision.p_d = 0;
        assert!(c.validate().is_err());
        let mut c = AcceleratorConfig::neural_pim();
        c.sa_per_array = 0;
        assert!(c.validate().is_err());
        let mut c = AcceleratorConfig::neural_pim();
        c.xbar_size = 1024;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_round_trip() {
        let c = AcceleratorConfig::cascade_like();
        let j = c.to_json();
        let c2 = AcceleratorConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn peak_gops_scales_with_dac_resolution() {
        let np = AcceleratorConfig::neural_pim(); // 2 input cycles
        let mut slow = np.clone();
        slow.precision.p_d = 1; // 8 input cycles
        assert!((np.peak_gops() / slow.peak_gops() - 4.0).abs() < 1e-9);
    }
}
