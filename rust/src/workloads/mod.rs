//! The paper's 9 DNN benchmarks (§6.1) as layer-shape descriptors, plus
//! the synthetic CNN the accuracy artifacts were trained on.
//!
//! The simulator only needs layer shapes (the authors' simulator is the
//! same kind of tool), so these are complete, faithful descriptions of
//! the public architectures: AlexNet, VGG-16/19, ResNet-50/101,
//! Inception-v3, GoogLeNet, MobileNet-V2 (all ImageNet-shaped) and the
//! NeuralTalk LSTM.

mod networks;

pub use networks::*;

/// One VMM-bearing layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// kernel height/width (1 for FC / LSTM gates)
    pub kh: u32,
    pub kw: u32,
    pub cin: u32,
    pub cout: u32,
    /// output spatial positions (sliding-window count); 1 for FC
    pub out_h: u32,
    pub out_w: u32,
    pub stride: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
    /// LSTM gate block: 4 gates x (W·x + U·h); modelled as FC with
    /// cin = input + hidden, cout = 4 * hidden, repeated per time step.
    Lstm,
}

impl Layer {
    pub fn conv(name: &str, kh: u32, cin: u32, cout: u32, out: u32,
                stride: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            kh,
            kw: kh,
            cin,
            cout,
            out_h: out,
            out_w: out,
            stride,
        }
    }

    pub fn fc(name: &str, cin: u32, cout: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Fc,
            kh: 1,
            kw: 1,
            cin,
            cout,
            out_h: 1,
            out_w: 1,
            stride: 1,
        }
    }

    pub fn lstm(name: &str, input: u32, hidden: u32, steps: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Lstm,
            kh: 1,
            kw: 1,
            cin: input + hidden,
            cout: 4 * hidden,
            // time steps take the role of sliding-window positions
            out_h: steps,
            out_w: 1,
            stride: 1,
        }
    }

    /// Rows a kernel needs in a crossbar: K = kh*kw*cin.
    pub fn k_dim(&self) -> u64 {
        self.kh as u64 * self.kw as u64 * self.cin as u64
    }

    /// Signed weights in this layer.
    pub fn weights(&self) -> u64 {
        self.k_dim() * self.cout as u64
    }

    /// Sliding-window positions to evaluate (per inference).
    pub fn positions(&self) -> u64 {
        self.out_h as u64 * self.out_w as u64
    }

    /// MAC operations per inference (x2 for the GOPS convention).
    pub fn macs(&self) -> u64 {
        self.weights() * self.positions()
    }

    /// Input activations consumed per position (bytes at 8-bit).
    pub fn input_bytes_per_position(&self) -> u64 {
        self.k_dim()
    }

    /// Output activations produced per position (bytes at 8-bit).
    pub fn output_bytes_per_position(&self) -> u64 {
        self.cout as u64
    }
}

/// A whole benchmark network.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: &'static str,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(Layer::weights).sum()
    }

    /// GOPs per inference (2 ops per MAC).
    pub fn gops(&self) -> f64 {
        2.0 * self.total_macs() as f64 / 1e9
    }
}

/// All nine §6.1 benchmarks in the paper's Fig. 12 order.
pub fn all_benchmarks() -> Vec<Network> {
    vec![
        alexnet(),
        vgg16(),
        vgg19(),
        resnet50(),
        resnet101(),
        googlenet(),
        inception_v3(),
        mobilenet_v2(),
        neuraltalk(),
    ]
}

pub fn by_name(name: &str) -> Option<Network> {
    let want = name.to_ascii_lowercase().replace(['-', '_'], "");
    all_benchmarks()
        .into_iter()
        .chain(std::iter::once(synthetic_cnn()))
        .find(|n| n.name.to_ascii_lowercase().replace(['-', '_'], "") == want)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_benchmarks_exist() {
        let b = all_benchmarks();
        assert_eq!(b.len(), 9);
        for n in &b {
            assert!(!n.layers.is_empty(), "{} has no layers", n.name);
            assert!(n.total_macs() > 0);
        }
    }

    #[test]
    fn alexnet_known_shape() {
        // AlexNet (ImageNet): ~61M weights, ~0.7G MACs
        let a = alexnet();
        let w = a.total_weights();
        assert!(w > 55_000_000 && w < 65_000_000, "weights {w}");
        let m = a.total_macs();
        assert!(m > 600_000_000 && m < 800_000_000, "macs {m}");
    }

    #[test]
    fn vgg16_known_shape() {
        // VGG-16: ~138M weights, ~15.5G MACs
        let v = vgg16();
        assert!((v.total_weights() as f64 - 138e6).abs() < 6e6,
                "weights {}", v.total_weights());
        assert!((v.total_macs() as f64 - 15.5e9).abs() < 1.0e9,
                "macs {}", v.total_macs());
    }

    #[test]
    fn resnet50_known_shape() {
        // ResNet-50: ~25.5M weights, ~3.9G MACs (conv+fc only ~25M/3.8G)
        let r = resnet50();
        let w = r.total_weights() as f64;
        assert!(w > 22e6 && w < 28e6, "weights {w}");
        let m = r.total_macs() as f64;
        assert!(m > 3.3e9 && m < 4.5e9, "macs {m}");
    }

    #[test]
    fn mobilenet_is_small() {
        let m = mobilenet_v2();
        assert!(m.total_macs() < resnet50().total_macs() / 5);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("AlexNet").is_some());
        assert!(by_name("resnet-50").is_some());
        assert!(by_name("neuraltalk").is_some());
        assert!(by_name("nope").is_none());
    }
}
