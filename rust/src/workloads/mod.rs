//! The paper's 9 DNN benchmarks (§6.1) as layer-shape descriptors, plus
//! the synthetic CNN the accuracy artifacts were trained on.
//!
//! The simulator only needs layer shapes (the authors' simulator is the
//! same kind of tool), so these are complete, faithful descriptions of
//! the public architectures: AlexNet, VGG-16/19, ResNet-50/101,
//! Inception-v3, GoogLeNet, MobileNet-V2 (all ImageNet-shaped) and the
//! NeuralTalk LSTM.

mod networks;

pub use networks::*;

use crate::util::cli;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::sync::{Arc, OnceLock};

/// One VMM-bearing layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// kernel height/width (1 for FC / LSTM gates)
    pub kh: u32,
    pub kw: u32,
    pub cin: u32,
    pub cout: u32,
    /// output spatial positions (sliding-window count); 1 for FC
    pub out_h: u32,
    pub out_w: u32,
    pub stride: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    Conv,
    Fc,
    /// LSTM gate block: 4 gates x (W·x + U·h); modelled as FC with
    /// cin = input + hidden, cout = 4 * hidden, repeated per time step.
    Lstm,
}

impl Layer {
    pub fn conv(name: &str, kh: u32, cin: u32, cout: u32, out: u32,
                stride: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            kh,
            kw: kh,
            cin,
            cout,
            out_h: out,
            out_w: out,
            stride,
        }
    }

    pub fn fc(name: &str, cin: u32, cout: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Fc,
            kh: 1,
            kw: 1,
            cin,
            cout,
            out_h: 1,
            out_w: 1,
            stride: 1,
        }
    }

    pub fn lstm(name: &str, input: u32, hidden: u32, steps: u32) -> Layer {
        Layer {
            name: name.into(),
            kind: LayerKind::Lstm,
            kh: 1,
            kw: 1,
            cin: input + hidden,
            cout: 4 * hidden,
            // time steps take the role of sliding-window positions
            out_h: steps,
            out_w: 1,
            stride: 1,
        }
    }

    /// Rows a kernel needs in a crossbar: K = kh*kw*cin.
    pub fn k_dim(&self) -> u64 {
        self.kh as u64 * self.kw as u64 * self.cin as u64
    }

    /// Signed weights in this layer.
    pub fn weights(&self) -> u64 {
        self.k_dim() * self.cout as u64
    }

    /// Sliding-window positions to evaluate (per inference).
    pub fn positions(&self) -> u64 {
        self.out_h as u64 * self.out_w as u64
    }

    /// MAC operations per inference (x2 for the GOPS convention).
    pub fn macs(&self) -> u64 {
        self.weights() * self.positions()
    }

    /// Input activations consumed per position (bytes at 8-bit).
    pub fn input_bytes_per_position(&self) -> u64 {
        self.k_dim()
    }

    /// Output activations produced per position (bytes at 8-bit).
    pub fn output_bytes_per_position(&self) -> u64 {
        self.cout as u64
    }
}

/// A whole benchmark network. The name is a shared `Arc<str>` (not a
/// `&'static str`) so networks can be defined at runtime — from a JSON
/// spec ([`from_spec`] / [`load`], the CLI's `--network-file`) — and
/// flow through `SimResult`, the event-simulator results, and the memo
/// cache exactly like the built-in benchmarks.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: Arc<str>,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(Layer::macs).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(Layer::weights).sum()
    }

    /// GOPs per inference (2 ops per MAC).
    pub fn gops(&self) -> f64 {
        2.0 * self.total_macs() as f64 / 1e9
    }
}

/// The built-in networks (nine benchmarks + the synthetic CNN), built
/// exactly once per process. Lookups and benchmark sweeps clone from
/// here instead of rebuilding every layer table per probe.
fn catalog() -> &'static [Network] {
    static CATALOG: OnceLock<Vec<Network>> = OnceLock::new();
    CATALOG.get_or_init(|| {
        vec![
            alexnet(),
            vgg16(),
            vgg19(),
            resnet50(),
            resnet101(),
            googlenet(),
            inception_v3(),
            mobilenet_v2(),
            neuraltalk(),
            synthetic_cnn(),
        ]
    })
}

/// All nine §6.1 benchmarks in the paper's Fig. 12 order.
pub fn all_benchmarks() -> Vec<Network> {
    catalog()[..9].to_vec()
}

/// Normalized lookup key: case-insensitive, punctuation-insensitive
/// ("VGG-16" == "vgg_16" == "Vgg 16").
fn normalize(name: &str) -> String {
    name.to_ascii_lowercase().replace(['-', '_', ' '], "")
}

/// Case/punctuation-insensitive lookup over the built-in catalog. Does
/// NOT rebuild the benchmark tables per probe — it matches against the
/// process-wide [`catalog`] and clones only the hit.
pub fn by_name(name: &str) -> Option<Network> {
    let want = normalize(name);
    catalog().iter().find(|n| normalize(&n.name) == want).cloned()
}

/// Build a [`Network`] from a JSON spec (the CLI's `--network-file`):
///
/// ```json
/// {
///   "name": "my-net",
///   "layers": [
///     {"kind": "conv", "name": "c1", "kh": 3, "cin": 3, "cout": 16,
///      "out": 32, "stride": 1},
///     {"kind": "fc", "cin": 1024, "cout": 10},
///     {"kind": "lstm", "input": 512, "hidden": 512, "steps": 20}
///   ]
/// }
/// ```
///
/// Conv layers accept `kw`/`out_w` overrides (default: square kernels
/// and outputs); `out`/`out_h` are synonyms; `stride` defaults to 1.
pub fn from_spec(j: &Json) -> Result<Network> {
    reject_unknown_fields(j, &["name", "layers"], "network spec")?;
    let name = j.get("name").and_then(Json::as_str).unwrap_or("custom");
    let layers_j = j
        .get("layers")
        .and_then(Json::as_arr)
        .context("network spec needs a 'layers' array")?;
    let mut layers = Vec::new();
    for (i, lj) in layers_j.iter().enumerate() {
        layers.push(
            layer_from_spec(lj, i).with_context(|| format!("layer {i}"))?,
        );
    }
    if layers.is_empty() {
        bail!("network spec has no layers");
    }
    Ok(Network { name: name.into(), layers })
}

/// Reject spec keys no known field matches, with a did-you-mean. A typo
/// like `"strid"` would otherwise be ignored and the field would
/// silently take its default — a wrong network, not an error.
fn reject_unknown_fields(j: &Json, known: &[&str], what: &str) -> Result<()> {
    let Json::Obj(map) = j else {
        bail!("{what} must be a JSON object (got {j})");
    };
    for key in map.keys() {
        if !known.contains(&key.as_str()) {
            match cli::suggest(key, known) {
                Some(s) => bail!(
                    "{what}: unknown field '{key}' (did you mean '{s}'?)"
                ),
                None => bail!("{what}: unknown field '{key}' (known: {})",
                              known.join(", ")),
            }
        }
    }
    Ok(())
}

fn layer_from_spec(j: &Json, index: usize) -> Result<Layer> {
    let num = |key: &str| j.get(key).and_then(Json::as_f64);
    let req = |key: &str| -> Result<u32> {
        let v = num(key).with_context(|| format!("missing field '{key}'"))?;
        if !(1.0..=u32::MAX as f64).contains(&v) || v.fract() != 0.0 {
            bail!("field '{key}' must be a positive integer (got {v})");
        }
        Ok(v as u32)
    };
    let fallback = format!("layer{index}");
    let name = j.get("name").and_then(Json::as_str).unwrap_or(&fallback);
    let kind = j.get("kind").and_then(Json::as_str).unwrap_or("conv");
    // each kind accepts exactly its own fields: an fc spec carrying
    // "steps" is as wrong as a misspelled key
    let known: &[&str] = match kind {
        "conv" => &["kind", "name", "kh", "kw", "cin", "cout", "out",
                    "out_h", "out_w", "stride"],
        "fc" => &["kind", "name", "cin", "cout"],
        "lstm" => &["kind", "name", "input", "hidden", "steps"],
        other => bail!("unknown layer kind '{other}' (conv | fc | lstm)"),
    };
    reject_unknown_fields(j, known, &format!("{kind} layer"))?;
    match kind {
        "conv" => {
            let kh = req("kh")?;
            let kw = if num("kw").is_some() { req("kw")? } else { kh };
            let out_h = if num("out_h").is_some() {
                req("out_h")?
            } else {
                req("out")?
            };
            let out_w = if num("out_w").is_some() { req("out_w")? } else { out_h };
            let stride = if num("stride").is_some() { req("stride")? } else { 1 };
            Ok(Layer {
                name: name.into(),
                kind: LayerKind::Conv,
                kh,
                kw,
                cin: req("cin")?,
                cout: req("cout")?,
                out_h,
                out_w,
                stride,
            })
        }
        "fc" => Ok(Layer::fc(name, req("cin")?, req("cout")?)),
        "lstm" => Ok(Layer::lstm(name, req("input")?, req("hidden")?,
                                 req("steps")?)),
        other => bail!("unknown layer kind '{other}' (conv | fc | lstm)"),
    }
}

/// Load a [`from_spec`] network from a JSON file.
pub fn load(path: &str) -> Result<Network> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading network spec {path}"))?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    from_spec(&j).with_context(|| format!("parsing network spec {path}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_benchmarks_exist() {
        let b = all_benchmarks();
        assert_eq!(b.len(), 9);
        for n in &b {
            assert!(!n.layers.is_empty(), "{} has no layers", n.name);
            assert!(n.total_macs() > 0);
        }
    }

    #[test]
    fn alexnet_known_shape() {
        // AlexNet (ImageNet): ~61M weights, ~0.7G MACs
        let a = alexnet();
        let w = a.total_weights();
        assert!(w > 55_000_000 && w < 65_000_000, "weights {w}");
        let m = a.total_macs();
        assert!(m > 600_000_000 && m < 800_000_000, "macs {m}");
    }

    #[test]
    fn vgg16_known_shape() {
        // VGG-16: ~138M weights, ~15.5G MACs
        let v = vgg16();
        assert!((v.total_weights() as f64 - 138e6).abs() < 6e6,
                "weights {}", v.total_weights());
        assert!((v.total_macs() as f64 - 15.5e9).abs() < 1.0e9,
                "macs {}", v.total_macs());
    }

    #[test]
    fn resnet50_known_shape() {
        // ResNet-50: ~25.5M weights, ~3.9G MACs (conv+fc only ~25M/3.8G)
        let r = resnet50();
        let w = r.total_weights() as f64;
        assert!(w > 22e6 && w < 28e6, "weights {w}");
        let m = r.total_macs() as f64;
        assert!(m > 3.3e9 && m < 4.5e9, "macs {m}");
    }

    #[test]
    fn mobilenet_is_small() {
        let m = mobilenet_v2();
        assert!(m.total_macs() < resnet50().total_macs() / 5);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("AlexNet").is_some());
        assert!(by_name("resnet-50").is_some());
        assert!(by_name("neuraltalk").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn lookup_is_case_and_punctuation_insensitive() {
        for probe in ["ALEXNET", "alex_net", "Alex Net", "aLeXnEt"] {
            let n = by_name(probe).unwrap_or_else(|| panic!("{probe}"));
            assert_eq!(n.name.as_ref(), "AlexNet", "{probe}");
        }
        for probe in ["VGG-16", "vgg_16", "Vgg 16", "vgg16"] {
            assert_eq!(by_name(probe).unwrap().name.as_ref(), "VGG-16");
        }
        assert_eq!(by_name("synthetic-cnn").unwrap().name.as_ref(),
                   "SyntheticCNN");
    }

    #[test]
    fn lookups_share_the_process_wide_catalog() {
        // by_name clones from the build-once catalog: names from two
        // probes alias the same Arc allocation instead of rebuilding
        // all nine benchmark tables per probe
        let a = by_name("googlenet").unwrap();
        let b = by_name("GoogLeNet").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a.name, &b.name));
        assert!(std::sync::Arc::ptr_eq(
            &all_benchmarks()[0].name,
            &by_name("alexnet").unwrap().name
        ));
    }

    #[test]
    fn from_spec_round_trip() {
        let spec = r#"{
            "name": "tiny",
            "layers": [
                {"kind": "conv", "name": "c1", "kh": 3, "cin": 3,
                 "cout": 16, "out": 12, "stride": 2},
                {"kind": "conv", "kh": 1, "kw": 3, "cin": 16, "cout": 8,
                 "out_h": 12, "out_w": 6},
                {"kind": "fc", "cin": 576, "cout": 10},
                {"kind": "lstm", "input": 64, "hidden": 32, "steps": 4}
            ]
        }"#;
        let net = from_spec(&Json::parse(spec).unwrap()).unwrap();
        assert_eq!(net.name.as_ref(), "tiny");
        assert_eq!(net.layers.len(), 4);
        let c1 = &net.layers[0];
        assert_eq!((c1.kh, c1.kw, c1.cin, c1.cout, c1.out_h, c1.stride),
                   (3, 3, 3, 16, 12, 2));
        let c2 = &net.layers[1];
        assert_eq!((c2.kh, c2.kw, c2.out_h, c2.out_w, c2.stride),
                   (1, 3, 12, 6, 1));
        assert_eq!(net.layers[1].name, "layer1"); // default name
        let l = &net.layers[3];
        assert_eq!(l.kind, LayerKind::Lstm);
        assert_eq!((l.cin, l.cout, l.out_h), (96, 128, 4));
        assert!(net.total_macs() > 0);
    }

    #[test]
    fn from_spec_rejects_unknown_fields_with_a_suggestion() {
        // a typo'd field would silently take its default otherwise
        let j = Json::parse(
            r#"{"layers": [{"kind": "conv", "kh": 3, "cin": 3, "cout": 16,
                            "out": 12, "strid": 2}]}"#,
        )
        .unwrap();
        let err = from_spec(&j).unwrap_err();
        assert!(format!("{err:#}").contains("did you mean 'stride'"),
                "{err:#}");
        // network-level keys are checked too
        let j = Json::parse(
            r#"{"nmae": "x",
                "layers": [{"kind": "fc", "cin": 4, "cout": 2}]}"#,
        )
        .unwrap();
        let err = from_spec(&j).unwrap_err();
        assert!(format!("{err:#}").contains("did you mean 'name'"),
                "{err:#}");
        // fields belonging to another kind don't leak across kinds
        let j = Json::parse(
            r#"{"layers": [{"kind": "fc", "cin": 4, "cout": 2,
                            "steps": 3}]}"#,
        )
        .unwrap();
        let err = from_spec(&j).unwrap_err();
        assert!(format!("{err:#}").contains("unknown field 'steps'"),
                "{err:#}");
        // far-from-anything keys list the known fields instead
        let j = Json::parse(
            r#"{"layers": [{"kind": "fc", "cin": 4, "cout": 2,
                            "zzzzzz": 3}]}"#,
        )
        .unwrap();
        let err = from_spec(&j).unwrap_err();
        assert!(format!("{err:#}").contains("known: kind, name, cin, cout"),
                "{err:#}");
    }

    #[test]
    fn from_spec_rejects_bad_input() {
        let bad = [
            r#"{"name": "x"}"#,                                   // no layers
            r#"{"layers": []}"#,                                  // empty
            r#"{"layers": [{"kind": "pool", "cin": 1}]}"#,        // kind
            r#"{"layers": [{"kind": "fc", "cin": 128}]}"#,        // missing
            r#"{"layers": [{"kind": "fc", "cin": 0, "cout": 1}]}"#, // zero
            r#"{"layers": [{"kind": "conv", "kh": 1.5, "cin": 1,
                            "cout": 1, "out": 1}]}"#,             // fraction
        ];
        for spec in bad {
            let j = Json::parse(spec).unwrap();
            assert!(from_spec(&j).is_err(), "{spec}");
        }
    }
}
