//! Layer tables for the nine §6.1 benchmarks. Shapes follow the original
//! publications (ImageNet input 224x224 / 227x227 / 299x299).

use super::{Layer, Network};

/// AlexNet (Krizhevsky et al., 2012), 227x227 input.
pub fn alexnet() -> Network {
    Network {
        name: "AlexNet".into(),
        layers: vec![
            Layer { name: "conv1".into(), kind: super::LayerKind::Conv,
                    kh: 11, kw: 11, cin: 3, cout: 96, out_h: 55, out_w: 55,
                    stride: 4 },
            // conv2/4/5 are 2-group convolutions in the original AlexNet:
            // each kernel sees half the input channels
            Layer { name: "conv2".into(), kind: super::LayerKind::Conv,
                    kh: 5, kw: 5, cin: 48, cout: 256, out_h: 27, out_w: 27,
                    stride: 1 },
            Layer::conv("conv3", 3, 256, 384, 13, 1),
            Layer::conv("conv4", 3, 192, 384, 13, 1),
            Layer::conv("conv5", 3, 192, 256, 13, 1),
            Layer::fc("fc6", 256 * 6 * 6, 4096),
            Layer::fc("fc7", 4096, 4096),
            Layer::fc("fc8", 4096, 1000),
        ],
    }
}

fn vgg_block(layers: &mut Vec<Layer>, tag: &str, n: u32, cin: u32, cout: u32,
             out: u32) {
    for i in 0..n {
        let name = format!("conv{}_{}", tag, i + 1);
        let ci = if i == 0 { cin } else { cout };
        layers.push(Layer::conv(&name, 3, ci, cout, out, 1));
    }
}

/// VGG-16 (Simonyan & Zisserman), 224x224.
pub fn vgg16() -> Network {
    let mut l = Vec::new();
    vgg_block(&mut l, "1", 2, 3, 64, 224);
    vgg_block(&mut l, "2", 2, 64, 128, 112);
    vgg_block(&mut l, "3", 3, 128, 256, 56);
    vgg_block(&mut l, "4", 3, 256, 512, 28);
    vgg_block(&mut l, "5", 3, 512, 512, 14);
    l.push(Layer::fc("fc6", 512 * 7 * 7, 4096));
    l.push(Layer::fc("fc7", 4096, 4096));
    l.push(Layer::fc("fc8", 4096, 1000));
    Network { name: "VGG-16".into(), layers: l }
}

/// VGG-19: the 4-conv variant of blocks 3-5.
pub fn vgg19() -> Network {
    let mut l = Vec::new();
    vgg_block(&mut l, "1", 2, 3, 64, 224);
    vgg_block(&mut l, "2", 2, 64, 128, 112);
    vgg_block(&mut l, "3", 4, 128, 256, 56);
    vgg_block(&mut l, "4", 4, 256, 512, 28);
    vgg_block(&mut l, "5", 4, 512, 512, 14);
    l.push(Layer::fc("fc6", 512 * 7 * 7, 4096));
    l.push(Layer::fc("fc7", 4096, 4096));
    l.push(Layer::fc("fc8", 4096, 1000));
    Network { name: "VGG-19".into(), layers: l }
}

/// ResNet bottleneck stage: `blocks` x [1x1 c, 3x3 c, 1x1 4c].
fn resnet_stage(l: &mut Vec<Layer>, tag: &str, blocks: u32, cin: u32, c: u32,
                out: u32, first_stride: u32) {
    let cout = 4 * c;
    for b in 0..blocks {
        let ci = if b == 0 { cin } else { cout };
        let s = if b == 0 { first_stride } else { 1 };
        l.push(Layer::conv(&format!("{}_{}a", tag, b), 1, ci, c, out, s));
        l.push(Layer::conv(&format!("{}_{}b", tag, b), 3, c, c, out, 1));
        l.push(Layer::conv(&format!("{}_{}c", tag, b), 1, c, cout, out, 1));
        if b == 0 {
            // projection shortcut
            l.push(Layer::conv(&format!("{}_{}p", tag, b), 1, ci, cout, out, s));
        }
    }
}

pub fn resnet50() -> Network {
    let mut l = vec![Layer { name: "conv1".into(),
                             kind: super::LayerKind::Conv, kh: 7, kw: 7,
                             cin: 3, cout: 64, out_h: 112, out_w: 112,
                             stride: 2 }];
    resnet_stage(&mut l, "res2", 3, 64, 64, 56, 1);
    resnet_stage(&mut l, "res3", 4, 256, 128, 28, 2);
    resnet_stage(&mut l, "res4", 6, 512, 256, 14, 2);
    resnet_stage(&mut l, "res5", 3, 1024, 512, 7, 2);
    l.push(Layer::fc("fc", 2048, 1000));
    Network { name: "ResNet-50".into(), layers: l }
}

pub fn resnet101() -> Network {
    let mut l = vec![Layer { name: "conv1".into(),
                             kind: super::LayerKind::Conv, kh: 7, kw: 7,
                             cin: 3, cout: 64, out_h: 112, out_w: 112,
                             stride: 2 }];
    resnet_stage(&mut l, "res2", 3, 64, 64, 56, 1);
    resnet_stage(&mut l, "res3", 4, 256, 128, 28, 2);
    resnet_stage(&mut l, "res4", 23, 512, 256, 14, 2);
    resnet_stage(&mut l, "res5", 3, 1024, 512, 7, 2);
    l.push(Layer::fc("fc", 2048, 1000));
    Network { name: "ResNet-101".into(), layers: l }
}

/// GoogLeNet (Inception-v1) inception module.
#[allow(clippy::too_many_arguments)] // mirrors the paper's table columns
fn inception_v1(l: &mut Vec<Layer>, tag: &str, cin: u32, out: u32,
                c1: u32, c3r: u32, c3: u32, c5r: u32, c5: u32, pp: u32) {
    l.push(Layer::conv(&format!("{}_1x1", tag), 1, cin, c1, out, 1));
    l.push(Layer::conv(&format!("{}_3x3r", tag), 1, cin, c3r, out, 1));
    l.push(Layer::conv(&format!("{}_3x3", tag), 3, c3r, c3, out, 1));
    l.push(Layer::conv(&format!("{}_5x5r", tag), 1, cin, c5r, out, 1));
    l.push(Layer { name: format!("{}_5x5", tag),
                   kind: super::LayerKind::Conv, kh: 5, kw: 5, cin: c5r,
                   cout: c5, out_h: out, out_w: out, stride: 1 });
    l.push(Layer::conv(&format!("{}_pool", tag), 1, cin, pp, out, 1));
}

pub fn googlenet() -> Network {
    let mut l = vec![
        Layer { name: "conv1".into(), kind: super::LayerKind::Conv,
                kh: 7, kw: 7, cin: 3, cout: 64, out_h: 112, out_w: 112,
                stride: 2 },
        Layer::conv("conv2r", 1, 64, 64, 56, 1),
        Layer::conv("conv2", 3, 64, 192, 56, 1),
    ];
    inception_v1(&mut l, "3a", 192, 28, 64, 96, 128, 16, 32, 32);
    inception_v1(&mut l, "3b", 256, 28, 128, 128, 192, 32, 96, 64);
    inception_v1(&mut l, "4a", 480, 14, 192, 96, 208, 16, 48, 64);
    inception_v1(&mut l, "4b", 512, 14, 160, 112, 224, 24, 64, 64);
    inception_v1(&mut l, "4c", 512, 14, 128, 128, 256, 24, 64, 64);
    inception_v1(&mut l, "4d", 512, 14, 112, 144, 288, 32, 64, 64);
    inception_v1(&mut l, "4e", 528, 14, 256, 160, 320, 32, 128, 128);
    inception_v1(&mut l, "5a", 832, 7, 256, 160, 320, 32, 128, 128);
    inception_v1(&mut l, "5b", 832, 7, 384, 192, 384, 48, 128, 128);
    l.push(Layer::fc("fc", 1024, 1000));
    Network { name: "GoogLeNet".into(), layers: l }
}

/// Inception-v3 (Szegedy et al. 2016), 299x299 — condensed but
/// MAC-faithful description of the stem + 11 inception blocks.
pub fn inception_v3() -> Network {
    let mut l = vec![
        Layer::conv("stem1", 3, 3, 32, 149, 2),
        Layer::conv("stem2", 3, 32, 32, 147, 1),
        Layer::conv("stem3", 3, 32, 64, 147, 1),
        Layer::conv("stem4", 1, 64, 80, 73, 1),
        Layer::conv("stem5", 3, 80, 192, 71, 1),
    ];
    // 3x block A at 35x35 (cin 192/256/288)
    for (i, cin) in [192u32, 256, 288].iter().enumerate() {
        let t = format!("a{}", i);
        l.push(Layer::conv(&format!("{t}_1x1"), 1, *cin, 64, 35, 1));
        l.push(Layer::conv(&format!("{t}_5x5r"), 1, *cin, 48, 35, 1));
        l.push(Layer { name: format!("{t}_5x5"),
                       kind: super::LayerKind::Conv, kh: 5, kw: 5, cin: 48,
                       cout: 64, out_h: 35, out_w: 35, stride: 1 });
        l.push(Layer::conv(&format!("{t}_3x3r"), 1, *cin, 64, 35, 1));
        l.push(Layer::conv(&format!("{t}_3x3a"), 3, 64, 96, 35, 1));
        l.push(Layer::conv(&format!("{t}_3x3b"), 3, 96, 96, 35, 1));
        l.push(Layer::conv(&format!("{t}_pool"), 1, *cin, if i == 0 { 32 } else { 64 }, 35, 1));
    }
    // reduction A
    l.push(Layer::conv("ra_3x3", 3, 288, 384, 17, 2));
    l.push(Layer::conv("ra_dbl_r", 1, 288, 64, 35, 1));
    l.push(Layer::conv("ra_dbl_a", 3, 64, 96, 35, 1));
    l.push(Layer::conv("ra_dbl_b", 3, 96, 96, 17, 2));
    // 4x block B at 17x17 (7x1/1x7 factorized convs), cin 768
    for (i, c7) in [128u32, 160, 160, 192].iter().enumerate() {
        let t = format!("b{}", i);
        l.push(Layer::conv(&format!("{t}_1x1"), 1, 768, 192, 17, 1));
        l.push(Layer::conv(&format!("{t}_7r"), 1, 768, *c7, 17, 1));
        l.push(Layer { name: format!("{t}_1x7"),
                       kind: super::LayerKind::Conv, kh: 1, kw: 7, cin: *c7,
                       cout: *c7, out_h: 17, out_w: 17, stride: 1 });
        l.push(Layer { name: format!("{t}_7x1"),
                       kind: super::LayerKind::Conv, kh: 7, kw: 1, cin: *c7,
                       cout: 192, out_h: 17, out_w: 17, stride: 1 });
        l.push(Layer::conv(&format!("{t}_dblr"), 1, 768, *c7, 17, 1));
        l.push(Layer { name: format!("{t}_dbl1"),
                       kind: super::LayerKind::Conv, kh: 7, kw: 1, cin: *c7,
                       cout: *c7, out_h: 17, out_w: 17, stride: 1 });
        l.push(Layer { name: format!("{t}_dbl2"),
                       kind: super::LayerKind::Conv, kh: 1, kw: 7, cin: *c7,
                       cout: *c7, out_h: 17, out_w: 17, stride: 1 });
        l.push(Layer { name: format!("{t}_dbl3"),
                       kind: super::LayerKind::Conv, kh: 7, kw: 1, cin: *c7,
                       cout: *c7, out_h: 17, out_w: 17, stride: 1 });
        l.push(Layer { name: format!("{t}_dbl4"),
                       kind: super::LayerKind::Conv, kh: 1, kw: 7, cin: *c7,
                       cout: 192, out_h: 17, out_w: 17, stride: 1 });
        l.push(Layer::conv(&format!("{t}_pool"), 1, 768, 192, 17, 1));
    }
    // reduction B + 2x block C at 8x8 (cin 1280/2048)
    l.push(Layer::conv("rb_r", 1, 768, 192, 17, 1));
    l.push(Layer::conv("rb_3x3", 3, 192, 320, 8, 2));
    for (i, cin) in [1280u32, 2048].iter().enumerate() {
        let t = format!("c{}", i);
        l.push(Layer::conv(&format!("{t}_1x1"), 1, *cin, 320, 8, 1));
        l.push(Layer::conv(&format!("{t}_3r"), 1, *cin, 384, 8, 1));
        l.push(Layer { name: format!("{t}_1x3"),
                       kind: super::LayerKind::Conv, kh: 1, kw: 3, cin: 384,
                       cout: 384, out_h: 8, out_w: 8, stride: 1 });
        l.push(Layer { name: format!("{t}_3x1"),
                       kind: super::LayerKind::Conv, kh: 3, kw: 1, cin: 384,
                       cout: 384, out_h: 8, out_w: 8, stride: 1 });
        l.push(Layer::conv(&format!("{t}_dr"), 1, *cin, 448, 8, 1));
        l.push(Layer::conv(&format!("{t}_d3"), 3, 448, 384, 8, 1));
        l.push(Layer { name: format!("{t}_d1x3"),
                       kind: super::LayerKind::Conv, kh: 1, kw: 3, cin: 384,
                       cout: 384, out_h: 8, out_w: 8, stride: 1 });
        l.push(Layer { name: format!("{t}_d3x1"),
                       kind: super::LayerKind::Conv, kh: 3, kw: 1, cin: 384,
                       cout: 384, out_h: 8, out_w: 8, stride: 1 });
        l.push(Layer::conv(&format!("{t}_pool"), 1, *cin, 192, 8, 1));
    }
    l.push(Layer::fc("fc", 2048, 1000));
    Network { name: "Inception-v3".into(), layers: l }
}

/// MobileNet-V2 (Sandler et al. 2018), 224x224. Depthwise convolutions
/// map to crossbars one channel per column group; modelled as grouped
/// layers with cin = kh*kw per output channel.
pub fn mobilenet_v2() -> Network {
    let mut l = vec![Layer::conv("conv0", 3, 3, 32, 112, 2)];
    // (expansion t, cout, n blocks, out size, stride of first)
    let cfg: [(u32, u32, u32, u32, u32); 7] = [
        (1, 16, 1, 112, 1),
        (6, 24, 2, 56, 2),
        (6, 32, 3, 28, 2),
        (6, 64, 4, 14, 2),
        (6, 96, 3, 14, 1),
        (6, 160, 3, 7, 2),
        (6, 320, 1, 7, 1),
    ];
    let mut cin = 32;
    for (bi, (t, cout, n, out, s)) in cfg.iter().enumerate() {
        for b in 0..*n {
            let stride = if b == 0 { *s } else { 1 };
            let hidden = cin * t;
            let tag = format!("ir{}_{}", bi, b);
            if *t != 1 {
                l.push(Layer::conv(&format!("{tag}_exp"), 1, cin, hidden, *out, 1));
            }
            // depthwise 3x3: per-channel kernels -> K = 9 rows per group
            l.push(Layer {
                name: format!("{tag}_dw"),
                kind: super::LayerKind::Conv,
                kh: 3, kw: 3,
                cin: 1, // per-group input depth
                cout: hidden,
                out_h: *out, out_w: *out,
                stride,
            });
            l.push(Layer::conv(&format!("{tag}_proj"), 1, hidden, *cout, *out, 1));
            cin = *cout;
        }
    }
    l.push(Layer::conv("conv_last", 1, 320, 1280, 7, 1));
    l.push(Layer::fc("fc", 1280, 1000));
    Network { name: "MobileNet-V2".into(), layers: l }
}

/// NeuralTalk-style image-captioning LSTM: VGG feature + LSTM-512
/// decoder over 20 tokens (the RNN benchmark of Fig. 12).
pub fn neuraltalk() -> Network {
    Network {
        name: "NeuralTalk".into(),
        layers: vec![
            Layer::fc("img_embed", 4096, 512),
            Layer::lstm("lstm1", 512, 512, 20),
            Layer::fc("word_out", 512, 8791),
        ],
    }
}

/// The synthetic-dataset CNN the accuracy artifacts run (train_cnn.py).
pub fn synthetic_cnn() -> Network {
    Network {
        name: "SyntheticCNN".into(),
        layers: vec![
            Layer::conv("conv1", 3, 3, 16, 12, 1),
            Layer::conv("conv2", 3, 16, 24, 6, 2),
            Layer::conv("conv3", 3, 24, 32, 6, 1),
            Layer::fc("fc", 32, 10),
        ],
    }
}
