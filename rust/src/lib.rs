//! # neural-pim
//!
//! Full-system reproduction of *Neural-PIM: Efficient Processing-In-Memory
//! with Neural Approximation of Peripherals* (IEEE TC 2022).
//!
//! Three layers:
//! - **L1** (build-time Python/Pallas): bit-sliced crossbar VMM, NNS+A and
//!   NNADC kernels — `python/compile/kernels/`.
//! - **L2** (build-time Python/JAX): quantized CNN under the three
//!   accumulation dataflows + NeuralPeriph training — lowered by
//!   `python/compile/aot.py` into `artifacts/*.hlo.txt`.
//! - **L3** (this crate): the architecture simulator, the §3 analytical
//!   framework, the `event` discrete-event microsimulator (contention-
//!   aware NoC + finite-buffer pipelines + tail-latency percentiles),
//!   the DSE engine, the PJRT runtime that executes the AOT artifacts,
//!   and the backend-agnostic serving layer. Python never runs at
//!   request time.
//!
//! Module map: `arch` (behavioural circuit models + c-mesh), `dataflow`
//! (§3 equations), `model` (the trait-based architecture cost-model
//! layer: one `CostModel` impl per architecture, the `ArchRegistry`
//! every comparison iterates, and the memoized per-`(network, config)`
//! `LayerCost` tables shared by the analytical and event simulators —
//! a hash-sharded, LRU-evicting cache with `memo.*` counters exported
//! into the `obs` Registry; register a new architecture by adding an
//! enum variant plus one impl
//! in `model/archs.rs`), `energy`/`mapping`/`sim` (budgets, replication
//! allocator, analytical system simulator), `event` (discrete-event
//! refinement of `sim`: slab-arena engine over a ladder queue with a
//! retained binary-heap differential reference, fast-path queued NoC,
//! back-pressured pipeline, cross-validation + sharded request-level
//! latency modes), `dse` (Fig. 11 sweep plus the streamed
//! ~1M-candidate fine grid behind `dse --fine`),
//! `noise`/`periph` (SINAD machinery, NeuralPeriph forwards),
//! `offload` (PIM + NPU hybrid deployment: a deterministic per-layer
//! placement search — exhaustive / seeded hill-climb / epsilon-greedy
//! bandit over the two pure memoized cost tables — minimizing EDP,
//! never worse than either pure extreme, surfaced as the `offload`
//! scenario),
//! `obs` (observability: the `Recorder` trait the event/serve hot
//! layers are generic over — zero-cost `NullRecorder` off-path, a
//! `TraceRecorder` exporting Perfetto-loadable Chrome trace JSON in
//! virtual picoseconds via `--trace` — plus the deterministic
//! counter/gauge/histogram `Registry` folded into every `event-sim`/
//! `serve-sim` outcome, and the leveled `diag!` stderr macro),
//! `runtime` (PJRT execution of the AOT artifacts), `serve` — the
//! backend-agnostic serving layer: an `InferenceBackend` trait (per-
//! worker-thread setup, `execute(batch) -> BatchResult`, declared
//! batch/classes/image shape) with two registered implementations
//! (`PjrtBackend` over the compiled artifacts, `SimBackend` priced by
//! `model::network_cost` + the `event` service-time model so serving
//! runs with zero artifacts), a backend-generic `Coordinator` with
//! admission control (bounded queue depth, typed `Rejection` responses)
//! and pluggable batch policy, typed `MetricsSnapshot` (counters, pad
//! fraction, p50/p95/p99, `last_error`) replacing the old summary
//! string, and a virtual-time load generator for the deterministic
//! `serve-sim` offered-load sweep; register a backend by implementing
//! the trait and listing it in `serve::BACKENDS` — `baselines`,
//! `config`, `report`, `workloads`, the `util` substrate (home of
//! `util::pool`, the persistent chunk-scheduling worker pool every
//! parallel sweep fans out over — nested maps run inline, results are
//! bit-identical at any `--threads`, and it is the crate's only thread
//! factory outside `serve/`), and
//! `scenario` — the
//! unified experiment layer: every CLI subcommand is a registered
//! `scenario::Scenario` with typed params and a typed `Outcome`
//! (text tables or schema-versioned JSON), executed through a
//! content-addressed results store (`--cache`) and composable into
//! JSON-defined suites (`neural-pim suite`). Register a new experiment
//! by implementing the trait and appending one line in
//! `scenario/registry.rs`.
//!
//! See DESIGN.md for the experiment index (which bench regenerates which
//! paper figure/table) and the fuller module map.

pub mod arch;
pub mod baselines;
pub mod config;
pub mod dataflow;
pub mod dse;
pub mod energy;
pub mod event;
pub mod mapping;
pub mod model;
pub mod noise;
pub mod obs;
pub mod offload;
pub mod periph;
pub mod report;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workloads;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifact directory: `$NEURAL_PIM_ARTIFACTS` or `artifacts/`
/// relative to the crate root (falls back to CWD).
pub fn artifact_dir() -> String {
    if let Ok(d) = std::env::var("NEURAL_PIM_ARTIFACTS") {
        return d;
    }
    let manifest = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(manifest).join("manifest.json").exists() {
        return manifest.to_string();
    }
    "artifacts".to_string()
}
